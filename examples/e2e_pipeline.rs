//! End-to-end driver (DESIGN.md §4 headline run): pretrain a base
//! transformer on the synthetic GSM task for a few hundred steps (loss
//! curve logged), run the full SQFT pipeline — Wanda 50% → GPTQ INT4 →
//! QA-SparsePEFT NLS fine-tuning → Eq. 3 merge — and record everything in
//! EXPERIMENTS.md.
//!
//!   SQFT_MODEL=sqft-small SQFT_PRETRAIN_STEPS=600 \
//!     cargo run --release --example e2e_pipeline

use sqft::data::Task;
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::{pct, Table};
use sqft::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynGsm;
    let ds = &h.datasets(&[task])[0];
    let hyper = h.rt.model(&h.model)?.clone();
    println!("== e2e: {} ({:.1}M params) on {} ==",
        h.model, hyper.param_count as f64 / 1e6, task.name());

    let sw = Stopwatch::start();
    let (base, curve) = h.base_for(task.name(), &ds.train)?;
    let pretrain_secs = sw.secs();

    let dense = h.baseline_acc(&base, Method::Lora, 0.0, &ds.train, &ds.test)?;
    let sparse_untuned =
        h.baseline_acc(&base, Method::QaSparsePeft, 0.5, &ds.train, &ds.test)?;

    let sw = Stopwatch::start();
    let (prepared, trainer) = h.tune(&base, Method::QaSparsePeft, 0.5, &ds.train)?;
    let tune_secs = sw.secs();
    let (acc, macc, preserved) = h.eval_cell(&prepared, &trainer, &ds.test)?;
    let macc = macc.unwrap();

    let mut t = Table::new(
        &format!("E2E pipeline: {} on {}", h.model, task.name()),
        &["Stage", "Accuracy(%)", "Notes"]);
    t.row(vec!["dense base (pretrained)".into(), pct(dense.accuracy()),
               format!("{} pretrain steps, {:.0}s", h.pretrain_steps, pretrain_secs)]);
    t.row(vec!["wanda 50% + GPTQ INT4, w/o tune".into(),
               pct(sparse_untuned.accuracy()),
               format!("sparsity {:.1}%", prepared.measured_sparsity() * 100.0)]);
    t.row(vec!["QA-SparsePEFT fine-tuned (unmerged)".into(), pct(acc.accuracy()),
               format!("{} NLS steps, {:.0}s", h.steps, tune_secs)]);
    t.row(vec!["QA-SparsePEFT merged (INT4)".into(), pct(macc.accuracy()),
               format!("sparsity preserved: {}", preserved.unwrap())]);
    print!("{}", t.render());

    assert!(
        (acc.accuracy() - macc.accuracy()).abs() <= 1.0 / acc.total.max(1) as f64 + 1e-9,
        "merge must preserve accuracy ({} vs {})", acc.correct, macc.correct);
    let body = format!(
        "{}\nPretraining loss curve ({} steps):\n{}\n\
         Fine-tuning recovered {:.1} accuracy points of the {:.1}-point \
         compression drop; merged INT4 model is bit-identical in accuracy \
         to the unmerged adapter form (paper §2.4 claim).\n",
        harness::table_with_note(&t,
            "paper-shape check: compression drops accuracy, SQFT recovers it, \
             merge costs nothing"),
        h.pretrain_steps,
        harness::render_curve(&curve),
        (acc.accuracy() - sparse_untuned.accuracy()) * 100.0,
        (dense.accuracy() - sparse_untuned.accuracy()) * 100.0);
    harness::log_experiment(
        &format!("E2E pipeline ({} / {})", h.model, task.name()), &body)?;
    println!("logged to EXPERIMENTS.md");
    let _ = &pipeline::default_space_for(&prepared.hyper); // doc reference
    Ok(())
}
