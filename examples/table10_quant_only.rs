//! Paper Table 10 (Appendix E): SQFT without sparsity — quantization only.
//!
//!   cargo run --release --example table10_quant_only

use sqft::data::Task;
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::report::{pct, Table};

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynGsm;
    let ds = &h.datasets(&[task])[0];
    let (base, _) = h.base_for(task.name(), &ds.train)?;

    let mut t = Table::new(
        &format!("Table 10 — quantization only, no sparsity ({})", h.model),
        &["Method", "Mergeable", "Final Precision", "Fine-tune", "Test Acc(%)"]);

    let dense = h.baseline_acc(&base, Method::Lora, 0.0, &ds.train, &ds.test)?;
    t.row(vec!["w/o tune".into(), "-".into(), "FP16".into(), "-".into(),
               pct(dense.accuracy())]);
    let q_untuned =
        h.baseline_acc(&base, Method::GptqLora, 0.0, &ds.train, &ds.test)?;
    t.row(vec!["w/o tune (GPTQ)".into(), "-".into(), "INT4".into(), "-".into(),
               pct(q_untuned.accuracy())]);

    for (method, ft) in [
        (Method::GptqLora, "LoRA"),
        (Method::Sqft, "NLS"),
        (Method::QaSparsePeft, "NLS"),
    ] {
        let (prepared, trainer) = h.tune(&base, method, 0.0, &ds.train)?;
        let (a, m, ok) = h.eval_cell(&prepared, &trainer, &ds.test)?;
        let shown = m.map(|x| x.accuracy()).unwrap_or(a.accuracy());
        let mut row = h.method_row(method, &[shown], ok);
        row.insert(3, ft.into());
        t.row(row);
        eprintln!("[table10] {} done: {}", method.name(), pct(shown));
    }

    print!("{}", t.render());
    harness::log_experiment(
        &format!("Table 10 ({} / {})", h.model, task.name()),
        &harness::table_with_note(&t,
            "paper-shape: GPTQ alone drops accuracy; fine-tuning recovers; \
             NLS > LoRA; QA-SparsePEFT trades a little accuracy for a pure \
             INT4 merged model"))?;
    Ok(())
}
