//! Paper Figure 5: accuracy across sparsity levels 0–80%, mergeable vs
//! non-mergeable methods, with the dense baseline — locating the critical
//! sparsity threshold (paper: a cliff between 60% and 70%).
//!
//!   cargo run --release --example fig5_sparsity_sweep

use sqft::data::Task;
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::report::{pct, Table};

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynGsm;
    let ds = &h.datasets(&[task])[0];
    let (base, _) = h.base_for(task.name(), &ds.train)?;
    let levels = [0.0, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
    let dense = h.baseline_acc(&base, Method::Lora, 0.0, &ds.train, &ds.test)?;

    let mut t = Table::new(
        &format!("Figure 5 — sparsity sweep ({} on {})", h.model, task.name()),
        &["Sparsity", "w/o tune", "Shears", "SparsePEFT", "QA-SparsePEFT"]);
    let mut series: Vec<(f64, [f64; 4])> = Vec::new();

    for &sp in &levels {
        let untuned = if sp == 0.0 {
            dense.accuracy()
        } else {
            h.baseline_acc(&base, Method::SparsePeft, sp, &ds.train, &ds.test)?
                .accuracy()
        };
        let mut row = [untuned, 0.0, 0.0, 0.0];
        for (i, method) in
            [Method::Shears, Method::SparsePeft, Method::QaSparsePeft]
                .into_iter()
                .enumerate()
        {
            let (prepared, trainer) = h.tune(&base, method, sp, &ds.train)?;
            let (a, m, _) = h.eval_cell(&prepared, &trainer, &ds.test)?;
            row[i + 1] = m.map(|x| x.accuracy()).unwrap_or(a.accuracy());
        }
        t.row(vec![
            format!("{:.0}%", sp * 100.0),
            pct(row[0]), pct(row[1]), pct(row[2]), pct(row[3]),
        ]);
        series.push((sp, row));
        eprintln!("[fig5] sparsity {:.0}% done", sp * 100.0);
    }

    print!("{}", t.render());
    // ascii plot of the SparsePEFT series (tuned)
    println!("accuracy vs sparsity (SparsePEFT, '#' = tuned, '.' = w/o tune):");
    for (sp, row) in &series {
        let bar = |v: f64| "#".repeat((v * 40.0).round() as usize);
        let dot = |v: f64| ".".repeat((v * 40.0).round() as usize);
        println!("{:>3.0}% |{:<40}|", sp * 100.0, bar(row[2]));
        println!("     |{:<40}|", dot(row[0]));
    }
    // locate the cliff: largest tuned-accuracy drop between adjacent levels
    let mut cliff = (0.0, 0.0, 0.0);
    for w in series.windows(2) {
        let drop = w[0].1[2] - w[1].1[2];
        if drop > cliff.2 {
            cliff = (w[0].0, w[1].0, drop);
        }
    }
    println!("largest tuned-accuracy drop: {:.0}% -> {:.0}% ({:+.1} pts)",
        cliff.0 * 100.0, cliff.1 * 100.0, -cliff.2 * 100.0);

    harness::log_experiment(
        &format!("Figure 5 ({} / {})", h.model, task.name()),
        &harness::table_with_note(&t,
            &format!("paper-shape: recovery holds through moderate sparsity, \
                      then a critical threshold; largest drop here between \
                      {:.0}% and {:.0}%; mergeable ≈ non-mergeable at every \
                      level", cliff.0 * 100.0, cliff.1 * 100.0)))?;
    Ok(())
}
