//! Paper Table 2: math instruction tuning — fine-tune once on the unified
//! math mixture (syn-gsm + syn-mawps + syn-svamp), evaluate per task +
//! average, all methods at 50% sparsity.
//!
//!   cargo run --release --example table2_math_instruct

use sqft::data::{Dataset, Task};
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::report::{pct, Table};

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let tasks = Task::math();
    let datasets = h.datasets(&tasks);
    let unified = Dataset::unified(&datasets, h.seed);
    let (base, _) = h.base_for("math", &unified)?;
    let sparsity = 0.5;

    let mut t = Table::new(
        &format!("Table 2 — {} math instruction tuning (50% sparsity)", h.model),
        &["Method", "Mergeable", "Final Precision",
          "syn-gsm", "syn-mawps", "syn-svamp", "Average"]);

    let eval_all = |prepared: &sqft::pipeline::Prepared,
                    trainer: &sqft::train::Trainer|
     -> anyhow::Result<(Vec<f64>, Option<bool>)> {
        let mut accs = Vec::new();
        let mut ok = None;
        for ds in &datasets {
            let (a, m, o) = h.eval_cell(prepared, trainer, &ds.test)?;
            accs.push(m.map(|x| x.accuracy()).unwrap_or(a.accuracy()));
            ok = ok.or(o);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        accs.push(avg);
        Ok((accs, ok))
    };

    // untuned references
    let mut untuned = vec![];
    for ds in &datasets {
        untuned.push(
            h.baseline_acc(&base, Method::SparsePeft, sparsity, &unified, &ds.test)?
                .accuracy());
    }
    let avg = untuned.iter().sum::<f64>() / untuned.len() as f64;
    let mut row = vec!["w/o tune (50% sparse)".into(), "-".into(), "FP16".into()];
    row.extend(untuned.iter().map(|&a| pct(a)));
    row.push(pct(avg));
    t.row(row);

    for method in [Method::Lora, Method::Shears, Method::SparsePeft,
                   Method::GptqLora, Method::Sqft, Method::QaSparsePeft] {
        let (prepared, trainer) = h.tune(&base, method, sparsity, &unified)?;
        let (accs, ok) = eval_all(&prepared, &trainer)?;
        t.row(h.method_row(method, &accs, ok));
        eprintln!("[table2] {} avg {}", method.name(), pct(*accs.last().unwrap()));
    }

    print!("{}", t.render());
    harness::log_experiment(
        &format!("Table 2 ({} / math instruct)", h.model),
        &harness::table_with_note(&t,
            "paper-shape: SparsePEFT tops or matches the FP16 block while \
             mergeable; QA-SparsePEFT competitive in the INT4 block"))?;
    Ok(())
}
