//! Paper Tables 6 + 7: cost analysis of the four pipeline configurations —
//! model storage, fine-tuning speed/memory, inference speed/memory —
//! measured on this testbed.
//!
//!   cargo run --release --example table7_cost_analysis
//!
//! Expected orderings (paper Table 6): storage 1 > 3 >> 2 > 4;
//! ft time 1 ≈ 2 < 3 ≈ 4; inference speed 4 > 2 > 3 > 1; inf mem 4<2<3<1.

use sqft::data::{Batcher, Task};
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::quant::pack::{fp16_storage_bytes, int4_storage_bytes};
use sqft::report::Table;
use sqft::serve::Engine;
use sqft::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynGsm;
    let ds = &h.datasets(&[task])[0];
    let (base, _) = h.base_for(task.name(), &ds.train)?;
    let hyper = h.rt.model(&h.model)?.clone();
    let sparsity = 0.5;

    // storage model: linear weights in base precision (+ packed groups for
    // INT4) + embed/norms FP16 + (unmerged only) FP16 adapters at r_max
    let linear_elems: Vec<(usize, usize)> = {
        let d = hyper.d_model;
        let ff = hyper.d_ff;
        let mut v = Vec::new();
        for _ in 0..hyper.n_layers {
            v.extend([(d, d), (d, d), (d, d), (d, d), (ff, d), (ff, d), (d, ff)]);
        }
        v
    };
    let other_bytes: usize =
        (hyper.vocab * hyper.d_model + hyper.d_model * (1 + 2 * hyper.n_layers)) * 2;
    let adapter_bytes: usize = hyper
        .mods
        .iter()
        .map(|m| {
            let (out, inp) = hyper.mod_dims(m);
            hyper.n_layers * hyper.r_max * (out + inp) * 2
        })
        .sum();
    let storage = |quant: bool, merged: bool| -> f64 {
        let w: usize = linear_elems
            .iter()
            .map(|&(o, i)| if quant {
                int4_storage_bytes(o, i, hyper.group_size)
                    .expect("config linear dims pack and group evenly")
            } else {
                fp16_storage_bytes(o, i)
            })
            .sum();
        (w + other_bytes + if merged { 0 } else { adapter_bytes }) as f64 / 1e6
    };

    let mut t = Table::new(
        &format!("Table 7 — cost analysis ({}, 50% sparsity)", h.model),
        &["ID", "Pipeline", "Mergeable", "Final Precision", "Storage (MB)",
          "FT steps/s", "FT state (MB)", "Inference req/s", "Inf weights (MB)"]);

    let methods = [
        ("1", Method::Shears),       // LoRA/Shears: FP16 + FP16
        ("2", Method::Sqft),         // INT4 + FP16
        ("3", Method::SparsePeft),   // FP16 merged
        ("4", Method::QaSparsePeft), // INT4 merged
    ];

    for (id, method) in methods {
        let (prepared, mut trainer) = h.tune(&base, method, sparsity, &ds.train)?;
        // fine-tuning speed: timed extra steps
        let batcher = Batcher::new(&ds.train, &h.tok, hyper.seq_len, hyper.batch);
        let mut rng = sqft::tensor::Rng::new(99);
        let warm = batcher.random_batch(&mut rng)?;
        trainer.step_batch(&warm, 1e-3)?;
        let sw = Stopwatch::start();
        let timed_steps = 10;
        for _ in 0..timed_steps {
            let b = batcher.random_batch(&mut rng)?;
            trainer.step_batch(&b, 1e-3)?;
        }
        let steps_per_sec = timed_steps as f64 / sw.secs();
        let ft_state_mb = trainer.trainable_bytes() as f64 / 1e6;

        // inference throughput: merged methods serve the folded model (no
        // adapter path); unmerged methods carry the adapter math forever
        let cfg = h.deploy_config(&trainer);
        let engine = if method.mergeable() {
            let merged = sqft::pipeline::merged_state(&prepared, &trainer, &cfg)?;
            let mut frozen = sqft::model::ParamSet::new();
            for (n, v) in merged.base.iter() {
                frozen.insert(n, v.clone());
            }
            for (n, v) in sqft::pipeline::dense_adapter_masks(&hyper).iter() {
                frozen.insert(n, v.clone());
            }
            Engine::new(&h.rt, &h.model, &frozen, None, "eval", 6)?
        } else {
            let frozen = prepared.frozen_set()?;
            Engine::new(&h.rt, &h.model, &frozen,
                        Some((&trainer.adapters, &trainer.space, &cfg)),
                        method.eval_kind(), 6)?
        };
        let mut grng = sqft::tensor::Rng::new(7);
        let requests: Vec<(Option<String>, String)> = (0..48)
            .map(|_| (None, task.gen_sample(&mut grng).prompt))
            .collect();
        // single-tenant flow through the engine's default adapter state;
        // coalesce up to the artifact batch like the old serve loop did
        let opts = sqft::serve::SchedulerOpts {
            max_batch: hyper.batch,
            ..Default::default()
        };
        let mut router = sqft::serve::Router::new(
            engine, sqft::serve::AdapterRegistry::new(1));
        let stats = sqft::serve::benchmark_router(
            &mut router, requests, std::time::Duration::from_millis(1), opts)?;

        let quant = method.quantized_base();
        let merged = method.mergeable();
        t.row(vec![
            id.into(),
            method.name().into(),
            if merged { "yes" } else { "no" }.into(),
            method.final_precision().into(),
            format!("{:.1}", storage(quant, merged)),
            format!("{:.2}", steps_per_sec),
            format!("{:.1}", ft_state_mb),
            format!("{:.1}", stats.total.throughput),
            format!("{:.1}", storage(quant, true)),
        ]);
        eprintln!("[table7] {} done", method.name());
    }

    print!("{}", t.render());
    harness::log_experiment(
        &format!("Tables 6+7 ({})", h.model),
        &harness::table_with_note(&t,
            "paper orderings to check: storage 1 > 3 >> 2 > 4; fine-tuning \
             speed 1 ≈ 2 >= 3 ≈ 4 (mask/fake-quant overhead); inference \
             weight footprint 4 < 2 < 3 < 1"))?;
    Ok(())
}
