"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

These are the core correctness signal for the whole stack — the AOT
artifacts embed exactly these kernels, so agreement here + artifact-level
integration tests on the rust side together certify the request path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal images: property tests skip, the rest run
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda f: f

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _MissingStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _MissingStrategies()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis is not installed"
)

from compile import kernels as K
from compile.kernels import ref
from compile.kernels.blocks import pick_block, vmem_bytes_f32

from conftest import rand_f32, rand_mask, rand_qparams

SHAPES = [
    # (M, K, N, r) — mixes block-divisible and odd sizes
    (8, 32, 16, 4),
    (16, 64, 64, 8),
    (128, 64, 128, 16),
    (4, 16, 8, 2),
    (384, 64, 128, 8),   # tiny-config projection shape (B*S=384)
    (6, 10, 14, 3),      # non-power-of-two everything
]


def _inputs(rng, m, k, n, r, sparsity=0.5, active=None):
    x = rand_f32(rng, (m, k))
    w = rand_f32(rng, (n, k))
    a = rand_f32(rng, (r, k), 0.1)
    b = rand_f32(rng, (n, r), 0.1)
    mask = rand_mask(rng, (n, k), sparsity)
    active = r if active is None else active
    rm = jnp.asarray([1.0] * active + [0.0] * (r - active), jnp.float32)
    scale = jnp.array([2.0 / max(active, 1)], jnp.float32)
    return x, w, a, b, mask, rm, scale


class TestSparseLoraMatmul:
    @pytest.mark.parametrize("m,k,n,r", SHAPES)
    def test_forward_matches_ref(self, rng, m, k, n, r):
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r)
        got = K.sparse_lora_matmul(x, w, a, b, mask, rm, scale)
        want = ref.sparse_lora_matmul(x, w, a, b, mask, rm, scale[0])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("active", [0, 1, 3])
    def test_elastic_rank(self, rng, active):
        """Deactivated rank components must not contribute at all."""
        m, k, n, r = 16, 32, 16, 4
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r, active=active)
        got = K.sparse_lora_matmul(x, w, a, b, mask, rm, scale)
        a_trunc = a.at[active:].set(0.0)
        want = ref.sparse_lora_matmul(x, w, a_trunc, b, mask, rm, scale[0])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_adapter_is_base_matmul(self, rng):
        m, k, n, r = 16, 32, 16, 4
        x, w, _, _, mask, rm, scale = _inputs(rng, m, k, n, r)
        za, zb = jnp.zeros((r, k)), jnp.zeros((n, r))
        got = K.sparse_lora_matmul(x, w, za, zb, mask, rm, scale)
        np.testing.assert_allclose(got, x @ w.T, rtol=1e-5, atol=1e-5)

    def test_full_mask_equals_dense_lora(self, rng):
        """mask=1 reduces SparsePEFT to plain LoRA — the paper's Fig. 1 left."""
        m, k, n, r = 16, 32, 16, 4
        x, w, a, b, _, rm, scale = _inputs(rng, m, k, n, r)
        ones = jnp.ones((n, k), jnp.float32)
        got = K.sparse_lora_matmul(x, w, a, b, ones, rm, scale)
        want = x @ (w + scale[0] * b @ a).T
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("m,k,n,r", SHAPES[:4])
    def test_grads_match_ref(self, rng, m, k, n, r):
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r)

        def lp(a_, b_, x_):
            return jnp.sum(K.sparse_lora_matmul(x_, w, a_, b_, mask, rm, scale) ** 2)

        def lr_(a_, b_, x_):
            return jnp.sum(ref.sparse_lora_matmul(x_, w, a_, b_, mask, rm, scale[0]) ** 2)

        gp = jax.grad(lp, argnums=(0, 1, 2))(a, b, x)
        gr = jax.grad(lr_, argnums=(0, 1, 2))(a, b, x)
        for p, q in zip(gp, gr):
            np.testing.assert_allclose(p, q, rtol=1e-4, atol=1e-4)

    def test_frozen_inputs_get_zero_grads(self, rng):
        m, k, n, r = 8, 16, 8, 2
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r)

        def lw(w_):
            return jnp.sum(K.sparse_lora_matmul(x, w_, a, b, mask, rm, scale))

        assert jnp.all(jax.grad(lw)(w) == 0.0)

    @needs_hypothesis
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 40), k=st.integers(1, 48),
        n=st.integers(1, 40), r=st.integers(1, 8),
        sparsity=st.floats(0.0, 0.95),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, r, sparsity, seed):
        rng = np.random.default_rng(seed)
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r, sparsity)
        got = K.sparse_lora_matmul(x, w, a, b, mask, rm, scale)
        want = ref.sparse_lora_matmul(x, w, a, b, mask, rm, scale[0])
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def _bank_inputs(rng, m, k, n, r, t, sparsity=0.5):
    """Random gathered-bank inputs; bank slot 0 is the identity (B=0)."""
    x = rand_f32(rng, (m, k))
    w = rand_f32(rng, (n, k))
    a_bank = rand_f32(rng, (t, r, k), 0.1)
    b_bank = rand_f32(rng, (t, n, r), 0.1)
    b_bank = b_bank.at[0].set(0.0)
    mask = rand_mask(rng, (n, k), sparsity)
    rm_bank = jnp.asarray(rng.integers(0, 2, size=(t, r)), jnp.float32)
    scale_bank = rand_f32(rng, (t,))
    idx = jnp.asarray(rng.integers(0, t, size=(m,)), jnp.int32)
    return x, w, a_bank, b_bank, mask, rm_bank, scale_bank, idx


class TestGatheredSparseLora:
    @pytest.mark.parametrize("m,k,n,r", SHAPES)
    def test_forward_matches_ref(self, rng, m, k, n, r):
        args = _bank_inputs(rng, m, k, n, r, t=5)
        got = K.gathered_sparse_lora_matmul(*args)
        want = ref.gathered_sparse_lora_matmul(*args)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rows_match_per_tenant_kernel(self, rng):
        """Each row of a mixed batch reproduces the per-tenant kernel's
        result for its own adapter — the mixed-batch correctness claim."""
        m, k, n, r, t = 16, 32, 16, 4, 5
        x, w, ab, bb, mask, rmb, sb, idx = _bank_inputs(rng, m, k, n, r, t)
        got = K.gathered_sparse_lora_matmul(x, w, ab, bb, mask, rmb, sb, idx)
        for i in range(m):
            ti = int(idx[i])
            row = K.sparse_lora_matmul(
                x[i:i + 1], w, ab[ti], bb[ti], mask, rmb[ti], sb[ti:ti + 1])
            np.testing.assert_allclose(got[i], row[0], rtol=1e-5, atol=1e-5)

    def test_identity_slot_is_base_matmul(self, rng):
        """Reserved bank slot 0 (B=0): rows indexed 0 see the plain base."""
        m, k, n, r, t = 16, 32, 16, 4, 3
        x, w, ab, bb, mask, rmb, sb, _ = _bank_inputs(rng, m, k, n, r, t)
        idx0 = jnp.zeros((m,), jnp.int32)
        got = K.gathered_sparse_lora_matmul(x, w, ab, bb, mask, rmb, sb, idx0)
        np.testing.assert_allclose(got, x @ w.T, rtol=1e-5, atol=1e-5)

    def test_uniform_batch_matches_same_tenant_kernel(self, rng):
        """All rows on one tenant == the same-tenant batched kernel."""
        m, k, n, r, t = 32, 64, 64, 8, 4
        x, w, ab, bb, mask, rmb, sb, _ = _bank_inputs(rng, m, k, n, r, t)
        for ti in range(t):
            idx = jnp.full((m,), ti, jnp.int32)
            got = K.gathered_sparse_lora_matmul(x, w, ab, bb, mask, rmb, sb, idx)
            want = K.sparse_lora_matmul(
                x, w, ab[ti], bb[ti], mask, rmb[ti], sb[ti:ti + 1])
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestQASparseLoraMatmul:
    @pytest.mark.parametrize("m,k,n,r", [(8, 32, 16, 4), (16, 64, 64, 8)])
    def test_forward_matches_ref(self, rng, m, k, n, r):
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r)
        g = max(k // 16, 1)
        scales, zeros = rand_qparams(rng, n, g)
        qmax = jnp.array([15.0], jnp.float32)
        got = K.qa_sparse_lora_matmul(x, w, a, b, mask, rm, scale, scales, zeros, qmax)
        want = ref.qa_sparse_lora_matmul(x, w, a, b, mask, rm, scale[0], scales, zeros, 15.0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_grads_match_ref_ste(self, rng):
        m, k, n, r = 8, 32, 16, 4
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r)
        scales, zeros = rand_qparams(rng, n, 2)
        qmax = jnp.array([15.0], jnp.float32)

        def lp(a_, b_, x_):
            return jnp.sum(
                K.qa_sparse_lora_matmul(x_, w, a_, b_, mask, rm, scale, scales, zeros, qmax) ** 2)

        def lr_(a_, b_, x_):
            return jnp.sum(
                ref.qa_sparse_lora_matmul(x_, w, a_, b_, mask, rm, scale[0], scales, zeros, 15.0) ** 2)

        gp = jax.grad(lp, argnums=(0, 1, 2))(a, b, x)
        gr = jax.grad(lr_, argnums=(0, 1, 2))(a, b, x)
        for p, q in zip(gp, gr):
            np.testing.assert_allclose(p, q, rtol=1e-4, atol=1e-4)

    def test_train_eval_merge_consistency(self, rng):
        """The QA forward equals an exact-merge then int4 serve — the paper's
        central QA-SparsePEFT claim (merge loses nothing)."""
        m, k, n, r = 8, 32, 16, 4
        x, w, a, b, mask, rm, scale = _inputs(rng, m, k, n, r)
        scales, zeros = rand_qparams(rng, n, 2)
        qmax = jnp.array([15.0], jnp.float32)
        y_train = K.qa_sparse_lora_matmul(x, w, a, b, mask, rm, scale, scales, zeros, qmax)
        merged = ref.effective_weight(w, a, b, mask, rm, scale[0])
        wq = ref.fake_quant(merged, scales, zeros, 15.0)
        np.testing.assert_allclose(y_train, x @ wq.T, rtol=1e-4, atol=1e-4)


class TestFakeQuant:
    @pytest.mark.parametrize("n,k,g", [(16, 32, 2), (64, 64, 4), (8, 16, 16)])
    def test_matches_ref(self, rng, n, k, g):
        w = rand_f32(rng, (n, k))
        scales, zeros = rand_qparams(rng, n, g)
        qmax = jnp.array([15.0], jnp.float32)
        got = K.fake_quant(w, scales, zeros, qmax)
        want = ref.fake_quant(w, scales, zeros, 15.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_codes_in_range_and_consistent(self, rng):
        n, k, g = 16, 32, 2
        w = rand_f32(rng, (n, k))
        scales, zeros = rand_qparams(rng, n, g)
        qmax = jnp.array([15.0], jnp.float32)
        codes = K.quantize_codes(w, scales, zeros, qmax)
        assert float(codes.min()) >= 0.0 and float(codes.max()) <= 15.0
        assert jnp.all(codes == jnp.round(codes))
        # dequantizing the codes reproduces fake_quant exactly (Eq. 4)
        gs = k // g
        cg = codes.reshape(n, g, gs)
        dq = ((cg - zeros[:, :, None]) * scales[:, :, None]).reshape(n, k)
        np.testing.assert_allclose(dq, K.fake_quant(w, scales, zeros, qmax),
                                   rtol=1e-6, atol=1e-6)

    def test_idempotent(self, rng):
        """fq(fq(w)) == fq(w): quantization is a projection."""
        n, k, g = 16, 32, 2
        w = rand_f32(rng, (n, k))
        scales, zeros = rand_qparams(rng, n, g)
        qmax = jnp.array([15.0], jnp.float32)
        fq1 = K.fake_quant(w, scales, zeros, qmax)
        fq2 = K.fake_quant(fq1, scales, zeros, qmax)
        np.testing.assert_allclose(fq1, fq2, rtol=1e-6, atol=1e-6)

    @needs_hypothesis
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 32), g=st.integers(1, 4),
           gs=st.integers(1, 8), seed=st.integers(0, 2**16))
    def test_hypothesis_sweep(self, n, g, gs, seed):
        rng = np.random.default_rng(seed)
        k = g * gs
        w = rand_f32(rng, (n, k))
        scales, zeros = rand_qparams(rng, n, g)
        qmax = jnp.array([15.0], jnp.float32)
        np.testing.assert_allclose(
            K.fake_quant(w, scales, zeros, qmax),
            ref.fake_quant(w, scales, zeros, 15.0),
            rtol=1e-5, atol=1e-5)


class TestWanda:
    @pytest.mark.parametrize("n,k", [(16, 32), (64, 64), (7, 13)])
    def test_matches_ref(self, rng, n, k):
        w = rand_f32(rng, (n, k))
        an = jnp.abs(rand_f32(rng, (k,)))
        np.testing.assert_allclose(K.wanda_score(w, an),
                                   ref.wanda_score(w, an),
                                   rtol=1e-6, atol=1e-6)

    def test_mask_sparsity_level(self, rng):
        w = rand_f32(rng, (32, 64))
        an = jnp.abs(rand_f32(rng, (64,)))
        m = ref.wanda_mask(w, an, 0.5)
        assert float(m.mean()) == pytest.approx(0.5)
        # per-row exactness (Wanda compares within output rows)
        np.testing.assert_allclose(np.asarray(m.sum(axis=1)), 32.0)


class TestInt4:
    @pytest.mark.parametrize("m,n,k,g", [(8, 16, 32, 2), (16, 64, 64, 4)])
    def test_matches_ref(self, rng, m, n, k, g):
        x = rand_f32(rng, (m, k))
        packed = jnp.asarray(rng.integers(0, 256, size=(n, k // 2)), jnp.uint8)
        scales, zeros = rand_qparams(rng, n, g)
        np.testing.assert_allclose(
            K.int4_matmul(x, packed, scales, zeros),
            ref.int4_matmul(x, packed, scales, zeros),
            rtol=1e-4, atol=1e-4)

    def test_unpack_nibble_order(self):
        packed = jnp.array([[0x21, 0x43]], jnp.uint8)  # low nibble first
        got = ref.unpack_int4(packed)
        np.testing.assert_array_equal(np.asarray(got), [[1, 2, 3, 4]])


class TestBlocks:
    def test_pick_block_divides(self):
        for dim in [1, 2, 7, 48, 64, 127, 128, 384, 2560]:
            b = pick_block(dim)
            assert dim % b == 0 and b <= 128

    def test_pick_block_prefers_large(self):
        assert pick_block(256) == 128
        assert pick_block(384) == 128
        assert pick_block(48) == 16

    def test_vmem_estimate(self):
        assert vmem_bytes_f32((128, 128)) == 128 * 128 * 4
        assert vmem_bytes_f32((2, 2), (3,)) == 16 + 12
