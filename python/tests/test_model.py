"""L2 correctness: the adapted transformer + train/eval/calib step builders.

Exercises the exact functions that aot.py lowers, in-process (interpret
pallas), so failures localize to the model rather than the PJRT bridge.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.CONFIGS["sqft-tiny"]


def init_base(rng, cfg=CFG, sparsity=0.0):
    base = {}
    for name, shape in M.base_param_specs(cfg):
        if name.startswith("ln") or name == "final_ln":
            base[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02 if name == "embed" else 1.0 / np.sqrt(shape[-1])
            base[name] = jnp.asarray(rng.normal(size=shape) * std, jnp.float32)
    return base


def init_adapters(rng, cfg=CFG, zero_b=True, mask_sparsity=0.0):
    ad = {}
    for name, shape in M.adapter_param_specs(cfg):
        if name.startswith("a_"):
            ad[name] = jnp.asarray(rng.normal(size=shape) * 0.02, jnp.float32)
        elif name.startswith("b_"):
            ad[name] = (jnp.zeros(shape, jnp.float32) if zero_b
                        else jnp.asarray(rng.normal(size=shape) * 0.02, jnp.float32))
        elif name.startswith("mask_"):
            ad[name] = jnp.asarray(rng.random(size=shape) >= mask_sparsity,
                                   jnp.float32)
        elif name.startswith("rankmask_"):
            ad[name] = jnp.ones(shape, jnp.float32)
        elif name.startswith("scale_"):
            ad[name] = jnp.full(shape, 2.0 / cfg.r_max, jnp.float32)
    return ad


def init_qa(rng, cfg=CFG):
    qa = {}
    for name, shape in M.qa_param_specs(cfg):
        if name.startswith("qscales_"):
            qa[name] = jnp.asarray(np.abs(rng.normal(size=shape)) * 0.05 + 0.02,
                                   jnp.float32)
        elif name.startswith("qzeros_"):
            qa[name] = jnp.asarray(rng.integers(4, 12, size=shape), jnp.float32)
        else:
            qa[name] = jnp.array([15.0], jnp.float32)
    return qa


def toy_batch(rng, cfg=CFG):
    """A trivially learnable task: predict (token + 1) mod vocab."""
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)), jnp.int32)
    targets = (tokens + 1) % cfg.vocab
    loss_mask = jnp.ones((cfg.batch, cfg.seq_len), jnp.float32)
    return tokens, targets, loss_mask


def flat_args(cfg, base, adapters, qa=None, opt=None, batch=None):
    args = [base[n] for n, _ in M.base_param_specs(cfg)]
    args += [adapters[n] for n, _ in M.adapter_param_specs(cfg)]
    if qa is not None:
        args += [qa[n] for n, _ in M.qa_param_specs(cfg)]
    if opt is not None:
        args += [opt[n] for n, _ in M.opt_param_specs(cfg)]
    if batch is not None:
        args += list(batch)
    return args


def zero_opt(cfg):
    return {n: jnp.zeros(s, jnp.float32) for n, s in M.opt_param_specs(cfg)}


class TestForward:
    def test_logits_shape_and_finite(self, rng):
        base = init_base(rng)
        ad = init_adapters(rng)
        tokens, _, _ = toy_batch(rng)
        logits = M.forward(CFG, base, ad, tokens)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causality(self, rng):
        """Changing a future token must not affect earlier logits."""
        base = init_base(rng)
        ad = init_adapters(rng)
        tokens, _, _ = toy_batch(rng)
        l1 = M.forward(CFG, base, ad, tokens)
        tok2 = tokens.at[:, -1].set((tokens[:, -1] + 3) % CFG.vocab)
        l2 = M.forward(CFG, base, ad, tok2)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-4, atol=1e-4)

    def test_zero_b_adapter_is_identity(self, rng):
        """LoRA init (B=0) leaves the base model unchanged."""
        base = init_base(rng)
        ad0 = init_adapters(rng, zero_b=True)
        tokens, _, _ = toy_batch(rng)
        l_ad = M.forward(CFG, base, ad0, tokens)
        ad_none = init_adapters(rng, zero_b=True)
        for m in M.MODS:
            ad_none[f"a_{m}"] = jnp.zeros_like(ad_none[f"a_{m}"])
        l_plain = M.forward(CFG, base, ad_none, tokens)
        np.testing.assert_allclose(l_ad, l_plain, rtol=1e-5, atol=1e-5)

    def test_merged_equals_unmerged_sparsepeft(self, rng):
        """Paper Eq. 2: folding L^p = (BA)⊙M into W^p is exact — the central
        SparsePEFT mergeability claim."""
        base = init_base(rng)
        ad = init_adapters(rng, zero_b=False, mask_sparsity=0.5)
        tokens, _, _ = toy_batch(rng)
        l_unmerged = M.forward(CFG, base, ad, tokens)

        merged = dict(base)
        zeroed = dict(ad)
        for m in M.MODS:
            key = {"q": "wq", "k": "wk", "v": "wv", "up": "wup", "down": "wdown"}[m]
            stacks = []
            for l in range(CFG.n_layers):
                stacks.append(ref.effective_weight(
                    base[key][l], ad[f"a_{m}"][l], ad[f"b_{m}"][l],
                    ad[f"mask_{m}"][l], ad[f"rankmask_{m}"][l],
                    ad[f"scale_{m}"][l]))
            merged[key] = jnp.stack(stacks)
            zeroed[f"b_{m}"] = jnp.zeros_like(ad[f"b_{m}"])
        l_merged = M.forward(CFG, merged, zeroed, tokens)
        np.testing.assert_allclose(l_unmerged, l_merged, rtol=1e-4, atol=1e-4)

    def test_merge_preserves_sparsity(self, rng):
        """S{W^p + L^p} ⊆ S{W^p}: merging never densifies (paper §2.3)."""
        base = init_base(rng)
        ad = init_adapters(rng, zero_b=False, mask_sparsity=0.6)
        for m in M.MODS:
            key = {"q": "wq", "k": "wk", "v": "wv", "up": "wup", "down": "wdown"}[m]
            w = base[key][0] * ad[f"mask_{m}"][0]
            merged = w + ref.sparse_lora_delta(
                ad[f"a_{m}"][0], ad[f"b_{m}"][0], ad[f"mask_{m}"][0],
                ad[f"rankmask_{m}"][0], ad[f"scale_{m}"][0])
            assert bool(jnp.all((ad[f"mask_{m}"][0] == 0) <= (merged == 0)))

    def test_qa_forward_equals_fakequant_merged(self, rng):
        base = init_base(rng)
        ad = init_adapters(rng, zero_b=False, mask_sparsity=0.5)
        qa = init_qa(rng)
        tokens, _, _ = toy_batch(rng)
        l_qa = M.forward(CFG, base, ad, tokens, qa=qa)

        merged = dict(base)
        zeroed = dict(ad)
        for m in M.MODS:
            key = {"q": "wq", "k": "wk", "v": "wv", "up": "wup", "down": "wdown"}[m]
            stacks = []
            for l in range(CFG.n_layers):
                eff = ref.effective_weight(
                    base[key][l], ad[f"a_{m}"][l], ad[f"b_{m}"][l],
                    ad[f"mask_{m}"][l], ad[f"rankmask_{m}"][l],
                    ad[f"scale_{m}"][l])
                stacks.append(ref.fake_quant(
                    eff, qa[f"qscales_{m}"][l], qa[f"qzeros_{m}"][l], 15.0))
            merged[key] = jnp.stack(stacks)
            zeroed[f"b_{m}"] = jnp.zeros_like(ad[f"b_{m}"])
        l_merged = M.forward(CFG, merged, zeroed, tokens)
        np.testing.assert_allclose(l_qa, l_merged, rtol=1e-4, atol=1e-4)


def pack_int4(codes):
    """(N, K) integer codes in [0, 15] -> (N, K//2) uint8, low nibble first
    (mirror of rust `quant::pack::pack_int4`, for test fixtures)."""
    c = np.asarray(codes, np.uint8)
    return jnp.asarray(c[:, 0::2] | (c[:, 1::2] << 4), jnp.uint8)


class TestForwardInt4:
    def _int4_params(self, rng, cfg=CFG):
        """A random fully-quantized merged model: codes + group params, plus
        the dense dequantized reference weights."""
        base = init_base(rng, cfg)
        params = {n: base[n] for n in ("embed", "final_ln", "ln1", "ln2")}
        dense = dict(base)
        for wkey in M.LINEAR_KEYS:
            out, inp = cfg.linear_dims(wkey)
            g = inp // cfg.group_size
            scales = jnp.asarray(
                np.abs(rng.normal(size=(cfg.n_layers, out, g))) * 0.05 + 0.02,
                jnp.float32)
            zeros = jnp.asarray(
                rng.integers(4, 12, size=(cfg.n_layers, out, g)), jnp.float32)
            codes = jnp.asarray(
                rng.integers(0, 16, size=(cfg.n_layers, out, inp)), jnp.float32)
            packed = jnp.stack(
                [pack_int4(codes[l]) for l in range(cfg.n_layers)])
            params[f"packed_{wkey}"] = packed
            params[f"qscales_{wkey}"] = scales
            params[f"qzeros_{wkey}"] = zeros
            cg = codes.reshape(cfg.n_layers, out, g, inp // g)
            dense[wkey] = ((cg - zeros[..., None]) * scales[..., None]).reshape(
                cfg.n_layers, out, inp)
        return params, dense

    def test_matches_dense_dequant_forward(self, rng):
        """The packed serving forward equals the plain forward over the
        dequantized dense weights — the whole INT4 path in one assert."""
        params, dense = self._int4_params(rng)
        tokens, _, _ = toy_batch(rng)
        l_int4 = M.forward_int4(CFG, params, tokens)
        l_dense = M.forward_plain(CFG, dense, tokens)
        assert l_int4.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
        np.testing.assert_allclose(l_int4, l_dense, rtol=1e-4, atol=1e-4)

    def test_eval_step_jits_with_u8_inputs(self, rng):
        """The exact function aot.py lowers accepts uint8 packed stacks."""
        params, _ = self._int4_params(rng)
        tokens, _, _ = toy_batch(rng)
        specs = M.eval_int4_input_specs(CFG)
        names = [n for n, _, _ in specs]
        assert names[-1] == "tokens" and len(names) == len(set(names))
        for n, shape, dtype in specs[:-1]:
            assert params[n].shape == shape and params[n].dtype == dtype, n
        fn = jax.jit(M.make_eval_int4_step(CFG))
        (logits,) = fn(*[params[n] for n in names[:-1]], tokens)
        ref_logits = M.forward_int4(CFG, params, tokens)
        np.testing.assert_allclose(logits, ref_logits, rtol=1e-5, atol=1e-5)


class TestForwardGathered:
    TENANTS = 3

    def _banks(self, rng, cfg=CFG):
        """Shared-base masks + per-tenant adapters stacked into banks.

        Returns (mask dict, per-tenant adapter dicts, bank dict).  Bank
        slot 0 is the identity adapter (B = 0); tenant t occupies slot
        t + 1.  All tenants share one Wanda mask — it is a property of
        the sparsified base, not of any adapter.
        """
        masks = {}
        for m in M.MODS:
            out, inp = cfg.mod_dims(m)
            masks[f"mask_{m}"] = jnp.asarray(
                rng.random(size=(cfg.n_layers, out, inp)) >= 0.5, jnp.float32)
        ads = []
        for _ in range(self.TENANTS):
            ad = init_adapters(rng, cfg, zero_b=False)
            ad.update(masks)
            ads.append(ad)
        banks = {n: np.zeros(s, np.float32)
                 for n, s in M.gathered_bank_specs(cfg)}
        for m in M.MODS:
            banks[f"rankmask_bank_{m}"][0] = 1.0
            banks[f"scale_bank_{m}"][0] = 1.0
            for t, ad in enumerate(ads):
                banks[f"a_bank_{m}"][t + 1] = ad[f"a_{m}"]
                banks[f"b_bank_{m}"][t + 1] = ad[f"b_{m}"]
                banks[f"rankmask_bank_{m}"][t + 1] = ad[f"rankmask_{m}"]
                banks[f"scale_bank_{m}"][t + 1] = ad[f"scale_{m}"]
        return masks, ads, {n: jnp.asarray(v) for n, v in banks.items()}

    def test_mixed_rows_match_per_tenant_forward(self, rng):
        """Row b of a mixed batch equals the same-tenant forward for that
        row's adapter; identity-slot rows equal the plain base forward."""
        base = init_base(rng)
        masks, ads, banks = self._banks(rng)
        tokens, _, _ = toy_batch(rng)
        params = dict(base, **masks, **banks)
        idx = jnp.asarray(
            [b % (self.TENANTS + 1) for b in range(CFG.batch)], jnp.int32)
        l_mixed = M.forward_gathered(CFG, params, tokens, idx)
        refs = [M.forward_plain(CFG, base, tokens)]
        refs += [M.forward(CFG, base, ad, tokens) for ad in ads]
        for b in range(CFG.batch):
            np.testing.assert_allclose(
                l_mixed[b], refs[int(idx[b])][b], rtol=1e-4, atol=1e-4)

    def test_uniform_batch_matches_single_tenant_forward(self, rng):
        """All rows on one slot reproduces the per-tenant engine's answer —
        the baseline the mixed scheduler must stay byte-identical to."""
        base = init_base(rng)
        masks, ads, banks = self._banks(rng)
        tokens, _, _ = toy_batch(rng)
        params = dict(base, **masks, **banks)
        idx = jnp.full((CFG.batch,), 2, jnp.int32)
        l_gathered = M.forward_gathered(CFG, params, tokens, idx)
        l_tenant = M.forward(CFG, base, ads[1], tokens)
        np.testing.assert_allclose(l_gathered, l_tenant, rtol=1e-4, atol=1e-4)

    def test_eval_step_jits_with_i32_index(self, rng):
        """The exact function aot.py lowers accepts the i32 index vector;
        unregistered (all-zero) slots act as identity."""
        base = init_base(rng)
        masks, _, banks = self._banks(rng)
        tokens, _, _ = toy_batch(rng)
        params = dict(base, **masks, **banks)
        idx = jnp.asarray(
            rng.integers(0, M.GATHER_SLOTS, size=(CFG.batch,)), jnp.int32)
        specs = M.eval_gathered_input_specs(CFG)
        names = [n for n, _, _ in specs]
        assert names[-2:] == ["tokens", "adapter_idx"]
        assert len(names) == len(set(names))
        for n, shape, dtype in specs[:-2]:
            assert params[n].shape == shape and params[n].dtype == dtype, n
        fn = jax.jit(M.make_eval_gathered_step(CFG))
        (logits,) = fn(*[params[n] for n in names[:-2]], tokens, idx)
        ref_logits = M.forward_gathered(CFG, params, tokens, idx)
        np.testing.assert_allclose(logits, ref_logits, rtol=1e-5, atol=1e-5)


class TestTrainStep:
    @pytest.mark.parametrize("qa", [False, True])
    def test_loss_decreases(self, rng, qa):
        cfg = CFG
        base = init_base(rng)
        ad = init_adapters(rng)
        qad = init_qa(rng) if qa else None
        opt = zero_opt(cfg)
        step_fn = jax.jit(M.make_train_step(cfg, qa=qa))
        tokens, targets, loss_mask = toy_batch(rng)
        losses = []
        for step in range(10):
            batch = (tokens, targets, loss_mask,
                     jnp.array([step + 1.0], jnp.float32),
                     jnp.array([2e-2], jnp.float32))
            args = flat_args(cfg, base, ad, qa=qad, opt=opt, batch=batch)
            outs = step_fn(*args)
            names = M.train_output_names(cfg)
            for n, o in zip(names[:10], outs[:10]):
                ad[n] = o
            for n, o in zip(names[10:30], outs[10:30]):
                ad  # noqa: B018 — opt update below
            trainable = [f"a_{m}" for m in M.MODS] + [f"b_{m}" for m in M.MODS]
            for j, n in enumerate(trainable):
                opt["m_" + n] = outs[10 + j]
                opt["v_" + n] = outs[20 + j]
            losses.append(float(outs[-1][0]))
        # fixed batch + Adam on the adapters: loss must fall monotonically
        # in trend and by a visible margin
        assert losses[-1] < losses[0] - 0.05, losses
        assert losses[-1] < min(losses[:3]), losses

    def test_base_weights_unchanged_by_construction(self, rng):
        """Train step outputs contain only adapter/opt tensors — the frozen
        base cannot drift (PEFT invariant)."""
        names = M.train_output_names(CFG)
        assert all(not n.startswith("w") and "embed" not in n for n in names)
        assert len(names) == 31


class TestCalibStep:
    def test_capture_shapes(self, rng):
        cfg = CFG
        base = init_base(rng)
        ad = init_adapters(rng)
        tokens, _, _ = toy_batch(rng)
        fn = M.make_calib_step(cfg)
        args = flat_args(cfg, base, ad, batch=(tokens,))
        logits, xqkv, xo, xmlp, xdown = fn(*args)
        t = cfg.batch * cfg.seq_len
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
        assert xqkv.shape == (cfg.n_layers, t, cfg.d_model)
        assert xo.shape == (cfg.n_layers, t, cfg.d_model)
        assert xmlp.shape == (cfg.n_layers, t, cfg.d_model)
        assert xdown.shape == (cfg.n_layers, t, cfg.d_ff)

    def test_capture_matches_plain_forward(self, rng):
        base = init_base(rng)
        ad = init_adapters(rng)
        tokens, _, _ = toy_batch(rng)
        fn = M.make_calib_step(CFG)
        args = flat_args(CFG, base, ad, batch=(tokens,))
        logits_c = fn(*args)[0]
        logits_p = M.forward(CFG, base, ad, tokens)
        np.testing.assert_allclose(logits_c, logits_p, rtol=1e-5, atol=1e-5)


class TestSpecs:
    @pytest.mark.parametrize("name", list(M.CONFIGS))
    def test_spec_shapes_consistent(self, name):
        cfg = M.CONFIGS[name]
        for specs in (M.train_input_specs(cfg, qa=False),
                      M.train_input_specs(cfg, qa=True),
                      M.eval_input_specs(cfg, qa=False),
                      M.eval_gathered_input_specs(cfg),
                      M.calib_input_specs(cfg)):
            names = [n for n, _, _ in specs]
            assert len(names) == len(set(names)), "duplicate input name"
        # group size must divide every adapted in-dim
        for m in M.MODS:
            _, inp = cfg.mod_dims(m)
            assert inp % cfg.group_size == 0

    @pytest.mark.parametrize("name", list(M.CONFIGS))
    def test_param_count_formula(self, name):
        cfg = M.CONFIGS[name]
        total = 0
        for _, shape in M.base_param_specs(cfg):
            n = 1
            for d in shape:
                n *= d
            total += n
        assert total == cfg.param_count()


class TestKvCache:
    """Prefill/decode_step split vs the full-forward reference.

    The rust engine's cached session is a straight transliteration of the
    chain below (prefill -> greedy append -> decode_step ...), so these
    are the ground-truth equivalence tests for serve_kv_cache.rs.
    """

    STEPS = 4

    def _chain(self, cfg, prefill_fn, decode_fn, full_fn, tokens, lens):
        """Greedy-extend every row STEPS tokens through the cached pair,
        checking frontier logits/argmax against the full forward over the
        growing buffer at every step."""
        off = 2 * cfg.n_layers * cfg.seq_len * cfg.d_model
        flat = np.array(tokens)
        lens = np.array(lens, np.int64)
        state = prefill_fn(jnp.asarray(flat, jnp.int32),
                           jnp.asarray(lens, jnp.int32))
        assert state.shape == (cfg.batch, M.kv_state_elems(cfg))
        for _ in range(self.STEPS):
            logits_c = np.asarray(state[:, off:])
            ref = np.asarray(full_fn(jnp.asarray(flat, jnp.int32)))
            for b in range(cfg.batch):
                row = ref[b, lens[b] - 1]
                np.testing.assert_allclose(logits_c[b], row,
                                           rtol=2e-4, atol=2e-4)
                assert int(np.argmax(logits_c[b])) == int(np.argmax(row))
            nxt = np.argmax(logits_c, axis=1).astype(np.int32)
            pos = lens.astype(np.int32)  # the new token's absolute position
            for b in range(cfg.batch):
                flat[b, lens[b]] = nxt[b]
            lens += 1
            state = decode_fn(state, jnp.asarray(nxt),
                              jnp.asarray(pos))

    def _prompts(self, rng, cfg=CFG):
        tokens = np.asarray(
            rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)),
            np.int32)
        # staggered prompt lengths so per-row positions genuinely differ
        lens = np.asarray(
            [3 + (b % 5) for b in range(cfg.batch)], np.int64)
        return tokens, lens

    def test_adapter_path_matches_full_forward(self, rng):
        base = init_base(rng)
        ad = init_adapters(rng, zero_b=False, mask_sparsity=0.5)
        tokens, lens = self._prompts(rng)
        lin = M._adapted_lin(CFG, base, ad)
        self._chain(
            CFG,
            lambda t, n: M._transformer_prefill(CFG, base, lin, t, n),
            lambda s, f, p: M._transformer_decode(CFG, base, lin, s, f, p),
            lambda t: M.forward(CFG, base, ad, t),
            tokens, lens)

    def test_gathered_path_matches_full_forward(self, rng):
        base = init_base(rng)
        g = TestForwardGathered()
        masks, _, banks = g._banks(rng)
        params = dict(base, **masks, **banks)
        idx = jnp.asarray(
            [b % (g.TENANTS + 1) for b in range(CFG.batch)], jnp.int32)
        tokens, lens = self._prompts(rng)
        self._chain(
            CFG,
            lambda t, n: M._transformer_prefill(
                CFG, params,
                M._gathered_lin(CFG, params,
                                jnp.repeat(idx, CFG.seq_len)), t, n),
            lambda s, f, p: M._transformer_decode(
                CFG, params, M._gathered_lin(CFG, params, idx), s, f, p),
            lambda t: M.forward_gathered(CFG, params, t, idx),
            tokens, lens)

    def test_int4_path_matches_full_forward(self, rng):
        params, _ = TestForwardInt4()._int4_params(rng)
        lin = M._int4_lin(params)
        tokens, lens = self._prompts(rng)
        self._chain(
            CFG,
            lambda t, n: M._transformer_prefill(CFG, params, lin, t, n),
            lambda s, f, p: M._transformer_decode(CFG, params, lin, s, f, p),
            lambda t: M.forward_int4(CFG, params, t),
            tokens, lens)

    def test_step_builders_jit_and_agree(self, rng):
        """The exact functions aot.py lowers: spec shapes line up and the
        jitted prefill/decode/decode_out agree with the raw chain."""
        base = init_base(rng)
        ad = init_adapters(rng, zero_b=False)
        tokens, lens = self._prompts(rng)
        pspecs = M.prefill_input_specs(CFG)
        dspecs = M.decode_input_specs(CFG)
        for specs in (pspecs, dspecs, M.prefill_gathered_input_specs(CFG),
                      M.decode_gathered_input_specs(CFG),
                      M.prefill_int4_input_specs(CFG),
                      M.decode_int4_input_specs(CFG)):
            names = [n for n, _, _ in specs]
            assert len(names) == len(set(names)), "duplicate input name"
        assert [n for n, _, _ in pspecs[-2:]] == ["tokens", "seq_lens"]
        assert [n for n, _, _ in dspecs[-3:]] == [
            "kv_state", "frontier", "positions"]
        args = flat_args(CFG, base, ad)
        (state,) = jax.jit(M.make_prefill_step(CFG))(
            *args, jnp.asarray(tokens), jnp.asarray(lens, jnp.int32))
        (logits,) = jax.jit(M.make_decode_out_step(CFG))(state)
        ref = M.forward(CFG, base, ad, jnp.asarray(tokens))
        for b in range(CFG.batch):
            np.testing.assert_allclose(
                logits[b], ref[b, int(lens[b]) - 1], rtol=2e-4, atol=2e-4)
        nxt = jnp.argmax(logits, axis=1).astype(jnp.int32)
        (state2,) = jax.jit(M.make_decode_step(CFG))(
            *args, state, nxt, jnp.asarray(lens, jnp.int32))
        assert state2.shape == (CFG.batch, M.kv_state_elems(CFG))
        assert bool(jnp.all(jnp.isfinite(state2[:, -CFG.vocab:])))

    def test_free_slot_rows_are_inert(self, rng):
        """len == 0 rows (free slots) must not disturb live rows — the
        engine prefills the whole slot bank, occupied or not."""
        base = init_base(rng)
        ad = init_adapters(rng, zero_b=False)
        tokens, lens = self._prompts(rng)
        lin = M._adapted_lin(CFG, base, ad)
        s1 = M._transformer_prefill(
            CFG, base, lin, jnp.asarray(tokens), jnp.asarray(lens, jnp.int32))
        tokens2 = np.array(tokens)
        tokens2[CFG.batch - 1] = 0
        lens2 = np.array(lens)
        lens2[CFG.batch - 1] = 0
        s2 = M._transformer_prefill(
            CFG, base, lin, jnp.asarray(tokens2),
            jnp.asarray(lens2, jnp.int32))
        np.testing.assert_allclose(s1[: CFG.batch - 1], s2[: CFG.batch - 1],
                                   rtol=1e-5, atol=1e-5)
