"""Shared fixtures/helpers for the SQFT python test suite."""

import numpy as np
import jax.numpy as jnp
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def rand_f32(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def rand_mask(rng, shape, sparsity=0.5):
    return jnp.asarray(rng.random(size=shape) >= sparsity, jnp.float32)


def rand_qparams(rng, n, g):
    scales = jnp.asarray(np.abs(rng.normal(size=(n, g))) + 0.05, jnp.float32)
    zeros = jnp.asarray(rng.integers(0, 16, size=(n, g)), jnp.float32)
    return scales, zeros
