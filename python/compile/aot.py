"""AOT compiler: lower every SQFT artifact to HLO *text* + manifest.json.

This is the only entry point that runs Python; after `make artifacts` the
rust binary is self-contained.  HLO text (not ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids that xla_extension 0.5.1 (the version behind the published ``xla`` crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Usage:
    python -m compile.aot --out-dir ../artifacts [--configs sqft-tiny,...]
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

DTYPES = {jnp.float32: "f32", jnp.int32: "i32", jnp.uint8: "u8"}


def to_hlo_text(lowered, tuple_out: bool = True) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    tuple_out=False lowers a single-result function with an *array* root
    instead of a one-element tuple: the rust runtime keeps such outputs
    device-resident (the KV state) and feeds them straight back into the
    next step, with no tuple decomposition — which would force a host
    download — in between.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=tuple_out
    )
    return comp.as_hlo_text()


def _specs_to_json(specs):
    out = []
    for name, shape, dtype in specs:
        out.append({
            "name": name,
            "shape": list(shape),
            "dtype": DTYPES[dtype],
        })
    return out


def _shape_structs(specs):
    return [jax.ShapeDtypeStruct(s, d) for _, s, d in specs]


def lower_artifact(fn, specs, path, tuple_out: bool = True):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*_shape_structs(specs))
    text = to_hlo_text(lowered, tuple_out=tuple_out)
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    print(f"  wrote {os.path.basename(path):40s} "
          f"{len(text) / 1e6:7.2f} MB  {time.time() - t0:6.1f}s")
    return digest


def build_config(cfg: M.ModelConfig, out_dir: str, manifest: dict):
    print(f"[aot] {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M params")
    entry = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len, "batch": cfg.batch,
            "r_max": cfg.r_max, "group_size": cfg.group_size,
            "param_count": cfg.param_count(),
            "mods": list(M.MODS),
            "mod_dims": {m: list(cfg.mod_dims(m)) for m in M.MODS},
        },
        "artifacts": {},
    }

    def art(kind, fn, specs, out_names, tuple_out=True):
        fname = f"{kind}_{cfg.name}.hlo.txt"
        digest = lower_artifact(fn, specs, os.path.join(out_dir, fname),
                                tuple_out=tuple_out)
        entry["artifacts"][kind] = {
            "file": fname,
            "inputs": _specs_to_json(specs),
            "outputs": out_names,
            "sha256_16": digest,
        }

    if not cfg.serve_only:
        art("pretrain", M.make_pretrain_step(cfg),
            M.pretrain_input_specs(cfg), M.pretrain_output_names(cfg))
        art("train", M.make_train_step(cfg, qa=False),
            M.train_input_specs(cfg, qa=False), M.train_output_names(cfg))
        art("train_qa", M.make_train_step(cfg, qa=True),
            M.train_input_specs(cfg, qa=True), M.train_output_names(cfg))
    art("eval", M.make_eval_step(cfg, qa=False),
        M.eval_input_specs(cfg, qa=False), ["logits"])
    if not cfg.serve_only:
        art("eval_qa", M.make_eval_step(cfg, qa=True),
            M.eval_input_specs(cfg, qa=True), ["logits"])
        art("eval_int4", M.make_eval_int4_step(cfg),
            M.eval_int4_input_specs(cfg), ["logits"])
        art("eval_gathered", M.make_eval_gathered_step(cfg),
            M.eval_gathered_input_specs(cfg), ["logits"])
        art("calib", M.make_calib_step(cfg),
            M.calib_input_specs(cfg), M.calib_output_names())

    # KV-cached decode split: single-array-result artifacts (tuple_out=False)
    # whose packed state output stays device-resident between steps.
    art("prefill", M.make_prefill_step(cfg),
        M.prefill_input_specs(cfg), ["kv_state"], tuple_out=False)
    art("decode", M.make_decode_step(cfg),
        M.decode_input_specs(cfg), ["kv_state"], tuple_out=False)
    art("decode_out", M.make_decode_out_step(cfg),
        M.decode_out_input_specs(cfg), ["logits"], tuple_out=False)
    if not cfg.serve_only:
        art("prefill_gathered", M.make_prefill_gathered_step(cfg),
            M.prefill_gathered_input_specs(cfg), ["kv_state"],
            tuple_out=False)
        art("decode_gathered", M.make_decode_gathered_step(cfg),
            M.decode_gathered_input_specs(cfg), ["kv_state"],
            tuple_out=False)
        art("prefill_int4", M.make_prefill_int4_step(cfg),
            M.prefill_int4_input_specs(cfg), ["kv_state"], tuple_out=False)
        art("decode_int4", M.make_decode_int4_step(cfg),
            M.decode_int4_input_specs(cfg), ["kv_state"], tuple_out=False)
    manifest["configs"][cfg.name] = entry

    # per-shape utility artifacts, deduped across configs
    for (m, n) in [] if cfg.serve_only else cfg.layer_shapes():
        wkey = f"wanda_{m}x{n}"
        if wkey not in manifest["shape_artifacts"]:
            specs = [("w", (m, n), jnp.float32), ("act_norm", (n,), jnp.float32)]
            fname = f"{wkey}.hlo.txt"
            digest = lower_artifact(M.make_wanda(m, n), specs,
                                    os.path.join(out_dir, fname))
            manifest["shape_artifacts"][wkey] = {
                "file": fname, "inputs": _specs_to_json(specs),
                "outputs": ["scores"], "sha256_16": digest,
            }
        g = n // cfg.group_size
        fkey = f"fakequant_{m}x{n}g{g}"
        if fkey not in manifest["shape_artifacts"]:
            specs = [
                ("w", (m, n), jnp.float32),
                ("scales", (m, g), jnp.float32),
                ("zeros", (m, g), jnp.float32),
                ("qmax", (1,), jnp.float32),
            ]
            fname = f"{fkey}.hlo.txt"
            digest = lower_artifact(M.make_fakequant(m, n, cfg.group_size),
                                    specs, os.path.join(out_dir, fname))
            manifest["shape_artifacts"][fkey] = {
                "file": fname, "inputs": _specs_to_json(specs),
                "outputs": ["dequant", "codes"], "sha256_16": digest,
            }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs",
                    default="sqft-tiny,sqft-small,sqft-base,sqft-large,"
                            "sqft-tiny-s96,sqft-tiny-s192")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "configs": {}, "shape_artifacts": {}}
    t0 = time.time()
    for name in args.configs.split(","):
        name = name.strip()
        if not name:
            continue
        build_config(M.CONFIGS[name], args.out_dir, manifest)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
