"""Wanda importance-score kernel:  Psi(W) = |W| * ||X||_2  (Sun et al. 2023).

The score is embarrassingly elementwise (one VPU pass over the weight tile
with the activation-norm vector broadcast from VMEM), so the kernel exists
mostly to keep the whole sparsification path inside the AOT artifact set —
the rust coordinator streams calibration batches through ``eval`` artifacts,
accumulates column norms, then runs this kernel per layer and does the
per-row top-k threshold on the host.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocks import pick_block


def _wanda_kernel(w_ref, n_ref, o_ref):
    o_ref[...] = jnp.abs(w_ref[...]) * n_ref[...][None, :]


def wanda_score(w, act_norm):
    """w: (N, K), act_norm: (K,) -> scores (N, K)."""
    n, k = w.shape
    bn = pick_block(n)
    return pl.pallas_call(
        _wanda_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), w.dtype),
        interpret=True,
    )(w, act_norm)
