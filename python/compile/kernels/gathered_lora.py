"""Gathered (multi-tenant) SparsePEFT projection kernel (Pallas).

The S-LoRA/punica-style serving hot-spot: one forward serves a *mixed*
batch where every row may belong to a different tenant.  Per-tenant
adapters are stacked into device-resident banks

    A_bank: (T, r, K)    B_bank: (T, N, r)
    rm_bank: (T, r)      scale_bank: (T,)

and a per-row i32 ``adapter_idx`` selects which slice applies:

    y[i] = x[i] @ (W + scale[t] * (B[t] diag(rm[t]) A[t]) .* M).T,
    t = adapter_idx[i]

Bank slot 0 is reserved for the **identity adapter** (B = 0), so rows
with no tenant (``adapter_id: None`` / the merged path) batch together
with adapted rows and still compute exactly ``x @ W.T``.

The Wanda sparsity mask ``M`` is a property of the shared sparsified
base, not of any tenant, so it stays a single (N, K) tensor rather than
a bank — every tenant's delta is pruned by the same base mask (paper
Eq. 1 semantics are unchanged).

Like the per-tenant kernel (sparse_lora.py), the effective weight is
rebuilt one VMEM tile at a time and never materialized in HBM; the only
difference is that each row of a tile gathers its own (r-skinny) bank
slice first.  The same reduction orders are used as in the per-tenant
kernel — one r-contraction for the delta, one K-contraction for the
output — so a mixed batch reproduces the per-tenant results exactly.

Serving-only: no custom_vjp (tenants fine-tune on the per-tenant path;
banks are frozen at registration).  Runs under ``interpret=True`` like
every L1 kernel; BlockSpecs stay MXU/VMEM-shaped for a real lowering.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocks import pick_block


def _gathered_fwd_kernel(x_ref, w_ref, a_ref, b_ref, m_ref, rm_ref, s_ref,
                         idx_ref, o_ref):
    """One (bm, bn) output tile with a per-row effective weight."""
    idx = idx_ref[...]                                  # (bm,) i32
    a_g = jnp.take(a_ref[...], idx, axis=0)             # (bm, r, K)
    b_g = jnp.take(b_ref[...], idx, axis=0)             # (bm, bn, r)
    rm_g = jnp.take(rm_ref[...], idx, axis=0)           # (bm, r)
    s_g = jnp.take(s_ref[...], idx, axis=0)             # (bm,)
    bt = b_g * rm_g[:, None, :]                         # (bm, bn, r)  VPU
    delta = jnp.einsum("xnr,xrk->xnk", bt, a_g)         # (bm, bn, K)  MXU
    weff = w_ref[...][None, :, :] + s_g[:, None, None] * delta * m_ref[...][None, :, :]
    o_ref[...] = jnp.einsum("xk,xnk->xn", x_ref[...], weff)  # (bm, bn)


def gathered_sparse_lora_matmul(x, w, a_bank, b_bank, mask, rm_bank,
                                scale_bank, adapter_idx):
    """Mixed-batch SparsePEFT projection over stacked adapter banks.

    x: (M, K), w: (N, K), a_bank: (T, r, K), b_bank: (T, N, r),
    mask: (N, K), rm_bank: (T, r), scale_bank: (T,),
    adapter_idx: (M,) int32 in [0, T)  ->  (M, N)
    """
    m_dim, k = x.shape
    n = w.shape[0]
    t, r = a_bank.shape[0], a_bank.shape[1]
    bm = pick_block(m_dim)
    bn = pick_block(n)
    grid = (m_dim // bm, n // bn)
    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),        # x
        pl.BlockSpec((bn, k), lambda i, j: (j, 0)),        # w
        pl.BlockSpec((t, r, k), lambda i, j: (0, 0, 0)),   # a_bank
        pl.BlockSpec((t, bn, r), lambda i, j: (0, j, 0)),  # b_bank
        pl.BlockSpec((bn, k), lambda i, j: (j, 0)),        # mask
        pl.BlockSpec((t, r), lambda i, j: (0, 0)),         # rm_bank
        pl.BlockSpec((t,), lambda i, j: (0,)),             # scale_bank
        pl.BlockSpec((bm,), lambda i, j: (i,)),            # adapter_idx
    ]
    return pl.pallas_call(
        _gathered_fwd_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n), x.dtype),
        interpret=True,
    )(x, w, a_bank, b_bank, mask, rm_bank, scale_bank, adapter_idx)
