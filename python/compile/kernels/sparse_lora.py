"""Fused SparsePEFT / QA-SparsePEFT projection kernels (Pallas).

The paper's compute hot-spot: every adapted linear layer evaluates

    y = x @ (W^p + scale * (B diag(rm) A) .* M).T            (SparsePEFT)
    y = x @ fq(W^p + scale * (B diag(rm) A) .* M).T          (QA-SparsePEFT)

where ``M`` is the Wanda sparsity mask, ``rm`` the NLS rank mask and ``fq``
the shared-scale fake quantizer (paper Eq. 1-4).  Instead of materializing the
effective weight in HBM (what a naive HF implementation does), the kernel
reconstructs one (bn, K) weight tile at a time in VMEM, applies mask (+ fake
quant) on the VPU, and feeds the MXU — so the dense delta never leaves
on-chip memory.  This is the TPU re-think of the paper's GPU kernels
(DESIGN.md §Hardware-Adaptation).

All kernels run under ``interpret=True`` (CPU PJRT); the BlockSpecs are
MXU/VMEM-shaped so the same code is valid for a real Mosaic lowering.

Gradients are provided via ``jax.custom_vjp`` with Pallas backward kernels:
interpret-mode ``pallas_call`` has no automatic VJP, and the backward pass is
itself a hot-spot (fine-tuning is the paper's workload).  Frozen inputs
(W, masks, quant params) receive zero cotangents.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocks import pick_block


# ---------------------------------------------------------------------------
# forward kernels
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w_ref, a_ref, b_ref, m_ref, rm_ref, s_ref, o_ref):
    """One (bm, bn) output tile: rebuild the effective-weight tile in VMEM."""
    scale = s_ref[0]
    bt = b_ref[...] * rm_ref[...][None, :]            # (bn, r)   VPU
    delta = jnp.dot(bt, a_ref[...])                   # (bn, K)   MXU (skinny)
    weff = w_ref[...] + scale * delta * m_ref[...]    # (bn, K)   VPU
    o_ref[...] = jnp.dot(x_ref[...], weff.T)          # (bm, bn)  MXU


def _qa_fwd_kernel(x_ref, w_ref, a_ref, b_ref, m_ref, rm_ref, s_ref,
                   qs_ref, qz_ref, qmax_ref, o_ref):
    """QA variant: fake-quantize the merged tile with shared scales/zeros."""
    scale = s_ref[0]
    qmax = qmax_ref[0]
    bt = b_ref[...] * rm_ref[...][None, :]
    delta = jnp.dot(bt, a_ref[...])
    merged = w_ref[...] + scale * delta * m_ref[...]  # (bn, K)
    bn, k = merged.shape
    g = qs_ref[...].shape[1]
    mg = merged.reshape(bn, g, k // g)
    q = jnp.clip(
        jnp.round(mg / qs_ref[...][:, :, None]) + qz_ref[...][:, :, None],
        0.0, qmax,
    )
    weff = ((q - qz_ref[...][:, :, None]) * qs_ref[...][:, :, None]).reshape(bn, k)
    o_ref[...] = jnp.dot(x_ref[...], weff.T)


def _fwd_call(x, w, a, b, mask, rank_mask, scale, qparams=None):
    m_dim, k = x.shape
    n = w.shape[0]
    r = a.shape[0]
    bm = pick_block(m_dim)
    bn = pick_block(n)
    grid = (m_dim // bm, n // bn)
    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),        # x
        pl.BlockSpec((bn, k), lambda i, j: (j, 0)),        # w
        pl.BlockSpec((r, k), lambda i, j: (0, 0)),         # a
        pl.BlockSpec((bn, r), lambda i, j: (j, 0)),        # b
        pl.BlockSpec((bn, k), lambda i, j: (j, 0)),        # mask
        pl.BlockSpec((r,), lambda i, j: (0,)),             # rank_mask
        pl.BlockSpec((1,), lambda i, j: (0,)),             # scale
    ]
    args = [x, w, a, b, mask, rank_mask, scale]
    kernel = _fwd_kernel
    if qparams is not None:
        qscales, qzeros, qmax = qparams
        g = qscales.shape[1]
        in_specs += [
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),    # scales
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),    # zeros
            pl.BlockSpec((1,), lambda i, j: (0,)),         # qmax
        ]
        args += [qscales, qzeros, qmax]
        kernel = _qa_fwd_kernel
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n), x.dtype),
        interpret=True,
    )(*args)


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, w_ref, a_ref, b_ref, m_ref, rm_ref, s_ref, dx_ref):
    """dx tile = g @ W_eff; the effective weight is recomputed, never stored."""
    scale = s_ref[0]
    bt = b_ref[...] * rm_ref[...][None, :]
    delta = jnp.dot(bt, a_ref[...])                   # (n, bk)
    weff = w_ref[...] + scale * delta * m_ref[...]
    dx_ref[...] = jnp.dot(g_ref[...], weff)           # (bm, bk)


def _qa_dx_kernel(g_ref, w_ref, a_ref, b_ref, m_ref, rm_ref, s_ref,
                  qs_ref, qz_ref, qmax_ref, dx_ref):
    scale = s_ref[0]
    qmax = qmax_ref[0]
    bt = b_ref[...] * rm_ref[...][None, :]
    delta = jnp.dot(bt, a_ref[...])
    merged = w_ref[...] + scale * delta * m_ref[...]
    n, bk = merged.shape
    g = qs_ref[...].shape[1]
    mg = merged.reshape(n, g, bk // g)
    q = jnp.clip(
        jnp.round(mg / qs_ref[...][:, :, None]) + qz_ref[...][:, :, None],
        0.0, qmax,
    )
    weff = ((q - qz_ref[...][:, :, None]) * qs_ref[...][:, :, None]).reshape(n, bk)
    dx_ref[...] = jnp.dot(g_ref[...], weff)


def _dab_kernel(g_ref, x_ref, a_ref, b_ref, m_ref, rm_ref, s_ref,
                da_ref, db_ref):
    """Adapter grads for one bn-slab of output features.

    dA accumulates across the N-grid (its block index is grid-invariant);
    dB is written per-slab.
    """
    i = pl.program_id(0)
    scale = s_ref[0]
    gmat = scale * jnp.dot(g_ref[...].T, x_ref[...]) * m_ref[...]  # (bn, K)
    at = rm_ref[...][:, None] * a_ref[...]                          # (r, K)
    db_ref[...] = jnp.dot(gmat, at.T)                               # (bn, r)
    contrib = rm_ref[...][:, None] * jnp.dot(b_ref[...].T, gmat)    # (r, K)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)

    da_ref[...] += contrib


def _qa_dab_kernel(g_ref, x_ref, w_ref, a_ref, b_ref, m_ref, rm_ref, s_ref,
                   qs_ref, qz_ref, qmax_ref, da_ref, db_ref):
    """QA adapter grads: clamp-aware STE gates the upstream cotangent."""
    i = pl.program_id(0)
    scale = s_ref[0]
    qmax = qmax_ref[0]
    bt = b_ref[...] * rm_ref[...][None, :]
    delta = jnp.dot(bt, a_ref[...])
    merged = w_ref[...] + scale * delta * m_ref[...]
    bn, k = merged.shape
    g = qs_ref[...].shape[1]
    mg = merged.reshape(bn, g, k // g)
    pre = jnp.round(mg / qs_ref[...][:, :, None]) + qz_ref[...][:, :, None]
    inside = ((pre >= 0.0) & (pre <= qmax)).astype(merged.dtype).reshape(bn, k)
    gmat = scale * jnp.dot(g_ref[...].T, x_ref[...]) * inside * m_ref[...]
    at = rm_ref[...][:, None] * a_ref[...]
    db_ref[...] = jnp.dot(gmat, at.T)
    contrib = rm_ref[...][:, None] * jnp.dot(b_ref[...].T, gmat)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)

    da_ref[...] += contrib


def _bwd_call(g, x, w, a, b, mask, rank_mask, scale, qparams=None):
    m_dim, k = x.shape
    n = w.shape[0]
    r = a.shape[0]
    # -- dx: grid over (M, K) tiles -------------------------------------
    bm = pick_block(m_dim)
    bk = pick_block(k)
    dx_specs = [
        pl.BlockSpec((bm, n), lambda i, j: (i, 0)),        # g
        pl.BlockSpec((n, bk), lambda i, j: (0, j)),        # w
        pl.BlockSpec((r, bk), lambda i, j: (0, j)),        # a
        pl.BlockSpec((n, r), lambda i, j: (0, 0)),         # b
        pl.BlockSpec((n, bk), lambda i, j: (0, j)),        # mask
        pl.BlockSpec((r,), lambda i, j: (0,)),             # rank_mask
        pl.BlockSpec((1,), lambda i, j: (0,)),             # scale
    ]
    dx_args = [g, w, a, b, mask, rank_mask, scale]
    dx_kernel = _dx_kernel
    qa = qparams is not None
    if qa:
        qscales, qzeros, qmax = qparams
        gq = qscales.shape[1]
        # quant groups tile along K: require the K-block to cover whole groups
        gs = k // gq
        while bk % gs != 0 and bk < k:
            bk *= 2
        bk = min(bk, k)
        dx_specs[1] = pl.BlockSpec((n, bk), lambda i, j: (0, j))
        dx_specs[2] = pl.BlockSpec((r, bk), lambda i, j: (0, j))
        dx_specs[4] = pl.BlockSpec((n, bk), lambda i, j: (0, j))
        bg = bk // gs
        dx_specs += [
            pl.BlockSpec((n, bg), lambda i, j: (0, j)),
            pl.BlockSpec((n, bg), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ]
        dx_args += [qscales, qzeros, qmax]
        dx_kernel = _qa_dx_kernel
    dx = pl.pallas_call(
        dx_kernel,
        grid=(m_dim // bm, k // bk),
        in_specs=dx_specs,
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k), x.dtype),
        interpret=True,
    )(*dx_args)

    # -- dA / dB: grid over N slabs -------------------------------------
    bn = pick_block(n)
    grid = (n // bn,)
    out_specs = [
        pl.BlockSpec((r, k), lambda i: (0, 0)),            # dA (accumulated)
        pl.BlockSpec((bn, r), lambda i: (i, 0)),           # dB
    ]
    out_shape = [
        jax.ShapeDtypeStruct((r, k), a.dtype),
        jax.ShapeDtypeStruct((n, r), b.dtype),
    ]
    if not qa:
        specs = [
            pl.BlockSpec((m_dim, bn), lambda i: (0, i)),   # g
            pl.BlockSpec((m_dim, k), lambda i: (0, 0)),    # x
            pl.BlockSpec((r, k), lambda i: (0, 0)),        # a
            pl.BlockSpec((bn, r), lambda i: (i, 0)),       # b
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # mask
            pl.BlockSpec((r,), lambda i: (0,)),            # rank_mask
            pl.BlockSpec((1,), lambda i: (0,)),            # scale
        ]
        da, db = pl.pallas_call(
            _dab_kernel,
            grid=grid,
            in_specs=specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=True,
        )(g, x, a, b, mask, rank_mask, scale)
    else:
        qscales, qzeros, qmax = qparams
        gq = qscales.shape[1]
        specs = [
            pl.BlockSpec((m_dim, bn), lambda i: (0, i)),   # g
            pl.BlockSpec((m_dim, k), lambda i: (0, 0)),    # x
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # w
            pl.BlockSpec((r, k), lambda i: (0, 0)),        # a
            pl.BlockSpec((bn, r), lambda i: (i, 0)),       # b
            pl.BlockSpec((bn, k), lambda i: (i, 0)),       # mask
            pl.BlockSpec((r,), lambda i: (0,)),            # rank_mask
            pl.BlockSpec((1,), lambda i: (0,)),            # scale
            pl.BlockSpec((bn, gq), lambda i: (i, 0)),      # scales
            pl.BlockSpec((bn, gq), lambda i: (i, 0)),      # zeros
            pl.BlockSpec((1,), lambda i: (0,)),            # qmax
        ]
        da, db = pl.pallas_call(
            _qa_dab_kernel,
            grid=grid,
            in_specs=specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=True,
        )(g, x, w, a, b, mask, rank_mask, scale, qscales, qzeros, qmax)
    return dx, da, db


# ---------------------------------------------------------------------------
# public custom_vjp entry points
# ---------------------------------------------------------------------------


@jax.custom_vjp
def sparse_lora_matmul(x, w, a, b, mask, rank_mask, scale):
    """y = x @ (W + scale*(B diag(rm) A) .* M).T  with Pallas fwd/bwd.

    Differentiable in ``x``, ``a``, ``b``; all other inputs are frozen and
    receive zero cotangents (the base model never trains under PEFT).
    """
    return _fwd_call(x, w, a, b, mask, rank_mask, scale)


def _fwd_rule(x, w, a, b, mask, rank_mask, scale):
    y = _fwd_call(x, w, a, b, mask, rank_mask, scale)
    return y, (x, w, a, b, mask, rank_mask, scale)


def _bwd_rule(res, g):
    x, w, a, b, mask, rank_mask, scale = res
    dx, da, db = _bwd_call(g, x, w, a, b, mask, rank_mask, scale)
    zeros = (
        jnp.zeros_like(w),
        jnp.zeros_like(mask),
        jnp.zeros_like(rank_mask),
        jnp.zeros_like(scale),
    )
    return (dx, zeros[0], da, db, zeros[1], zeros[2], zeros[3])


sparse_lora_matmul.defvjp(_fwd_rule, _bwd_rule)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def qa_sparse_lora_matmul(x, w, a, b, mask, rank_mask, scale,
                          qscales, qzeros, qmax):
    """QA-SparsePEFT projection: y = x @ fq(W + (BA).*M).T (paper Eq. 3-4).

    The fake quantizer shares the base model's group scales/zeros; training
    through it means the post-hoc merge is exactly the deployed function.
    Clamp-aware STE gradient.
    """
    return _fwd_call(x, w, a, b, mask, rank_mask, scale,
                     qparams=(qscales, qzeros, qmax))


def _qa_fwd_rule(x, w, a, b, mask, rank_mask, scale, qscales, qzeros, qmax):
    y = _fwd_call(x, w, a, b, mask, rank_mask, scale,
                  qparams=(qscales, qzeros, qmax))
    return y, (x, w, a, b, mask, rank_mask, scale, qscales, qzeros, qmax)


def _qa_bwd_rule(res, g):
    x, w, a, b, mask, rank_mask, scale, qscales, qzeros, qmax = res
    dx, da, db = _bwd_call(g, x, w, a, b, mask, rank_mask, scale,
                           qparams=(qscales, qzeros, qmax))
    return (
        dx,
        jnp.zeros_like(w),
        da,
        db,
        jnp.zeros_like(mask),
        jnp.zeros_like(rank_mask),
        jnp.zeros_like(scale),
        jnp.zeros_like(qscales),
        jnp.zeros_like(qzeros),
        jnp.zeros_like(qmax),
    )


qa_sparse_lora_matmul.defvjp(_qa_fwd_rule, _qa_bwd_rule)
