"""Layer-1 Pallas kernels for SQFT (interpret=True; see DESIGN.md §2).

Public surface consumed by the Layer-2 model:
  - sparse_lora_matmul / qa_sparse_lora_matmul  (fused adapted projections)
  - gathered_sparse_lora_matmul                 (mixed-tenant adapter banks)
  - fake_quant / quantize_codes                 (paper Eq. 3-4 merge path)
  - wanda_score                                 (sparsification scoring)
  - int4_matmul                                 (packed serving path)
Reference semantics live in kernels.ref.
"""

from . import ref  # noqa: F401
from .fake_quant import fake_quant, quantize_codes  # noqa: F401
from .gathered_lora import gathered_sparse_lora_matmul  # noqa: F401
from .int4 import int4_matmul  # noqa: F401
from .sparse_lora import qa_sparse_lora_matmul, sparse_lora_matmul  # noqa: F401
from .wanda import wanda_score  # noqa: F401
