"""Pure-jnp reference oracles for the SQFT Pallas kernels.

Every kernel in this package has an exact functional counterpart here.  The
pytest suite (python/tests/) asserts allclose between the Pallas
(interpret=True) implementations and these references across shape/dtype
sweeps, and checks the custom_vjp gradients against jax autodiff of these
references.  These are the single source of truth for kernel semantics.

Conventions (shared by kernels, model.py and the rust coordinator):
  - Linear layers compute ``y = x @ W.T`` with ``W: (out_features, in_features)``.
  - LoRA adapters: ``A: (r_max, in)``, ``B: (out, r_max)``; the dense delta is
    ``B @ A``.  NLS elastic rank is expressed with a 0/1 ``rank_mask: (r_max,)``
    that deactivates trailing rank components; ``scale`` is ``alpha / r_active``
    and is supplied by the coordinator as a scalar.
  - SparsePEFT (paper Eq. 1): the delta is multiplied elementwise by the binary
    sparsity mask ``M`` of the base weight before it touches the activations,
    so merging (Eq. 2) can never densify the base model.
  - Fake quantization (paper Eq. 3-4): asymmetric, group-wise along the input
    dimension; ``q = clamp(round(w/s) + z, 0, qmax)``; dequant ``s * (q - z)``.
"""

import jax
import jax.numpy as jnp


def lora_delta(a, b, rank_mask, scale):
    """Dense (unmasked) low-rank delta ``scale * B @ diag(rank_mask) @ A``."""
    return scale * (b * rank_mask[None, :]) @ a


def sparse_lora_delta(a, b, mask, rank_mask, scale):
    """SparsePEFT delta  L^p = (B A) .* M   (paper Eq. 1), elastic-rank form."""
    return lora_delta(a, b, rank_mask, scale) * mask


def effective_weight(w, a, b, mask, rank_mask, scale):
    """W^p + L^p  (paper Eq. 2) — the merged weight SparsePEFT trains against."""
    return w + sparse_lora_delta(a, b, mask, rank_mask, scale)


def sparse_lora_matmul(x, w, a, b, mask, rank_mask, scale):
    """Fused SparsePEFT projection  y = x @ (W^p + (BA) .* M).T.

    x: (M, K), w: (N, K), a: (r, K), b: (N, r), mask: (N, K),
    rank_mask: (r,), scale: scalar  ->  (M, N)
    """
    return x @ effective_weight(w, a, b, mask, rank_mask, scale).T


def gathered_sparse_lora_matmul(x, w, a_bank, b_bank, mask, rm_bank,
                                scale_bank, adapter_idx):
    """Mixed-batch SparsePEFT projection: row i uses bank slice
    ``t = adapter_idx[i]``.

    x: (M, K), w: (N, K), a_bank: (T, r, K), b_bank: (T, N, r),
    mask: (N, K), rm_bank: (T, r), scale_bank: (T,),
    adapter_idx: (M,) int32  ->  (M, N)

    Bank slot 0 holds the identity adapter (B = 0), so index-0 rows
    compute exactly ``x @ W.T`` (the merged / no-adapter path).
    """
    a_g = jnp.take(a_bank, adapter_idx, axis=0)          # (M, r, K)
    b_g = jnp.take(b_bank, adapter_idx, axis=0)          # (M, N, r)
    rm_g = jnp.take(rm_bank, adapter_idx, axis=0)        # (M, r)
    s_g = jnp.take(scale_bank, adapter_idx, axis=0)      # (M,)
    bt = b_g * rm_g[:, None, :]
    delta = jnp.einsum("xnr,xrk->xnk", bt, a_g)
    weff = w[None, :, :] + s_g[:, None, None] * delta * mask[None, :, :]
    return jnp.einsum("xk,xnk->xn", x, weff)


def fake_quant(w, scales, zeros, qmax):
    """Group-wise asymmetric fake quantization (paper Eq. 3 then Eq. 4).

    w: (N, K), scales/zeros: (N, G) with group size K // G.
    """
    n, k = w.shape
    g = scales.shape[1]
    gs = k // g
    wg = w.reshape(n, g, gs)
    q = jnp.clip(jnp.round(wg / scales[:, :, None]) + zeros[:, :, None], 0, qmax)
    return ((q - zeros[:, :, None]) * scales[:, :, None]).reshape(n, k)


def fake_quant_ste(w, scales, zeros, qmax):
    """fake_quant with a clamp-aware straight-through estimator.

    Gradient flows through positions whose pre-clamp quantized value lies in
    [0, qmax]; clamped positions get zero gradient.  This is the function the
    QA-SparsePEFT train step differentiates through.
    """
    n, k = w.shape
    g = scales.shape[1]
    gs = k // g
    wg = w.reshape(n, g, gs)
    pre = jnp.round(wg / scales[:, :, None]) + zeros[:, :, None]
    inside = ((pre >= 0) & (pre <= qmax)).astype(w.dtype).reshape(n, k)
    dq = fake_quant(w, scales, zeros, qmax)
    return w * inside + jax.lax.stop_gradient(dq - w * inside)


def qa_merged_weight(w, a, b, mask, rank_mask, scale, scales, zeros, qmax):
    """QA-SparsePEFT effective weight: fake-quantized (W^p + L^p) with the
    base model's shared scales/zeros (paper Eq. 3-4, STE for training)."""
    merged = effective_weight(w, a, b, mask, rank_mask, scale)
    return fake_quant_ste(merged, scales, zeros, qmax)


def qa_sparse_lora_matmul(x, w, a, b, mask, rank_mask, scale, scales, zeros, qmax):
    """Fused QA-SparsePEFT projection  y = x @ fq(W^p + L^p).T."""
    return x @ qa_merged_weight(
        w, a, b, mask, rank_mask, scale, scales, zeros, qmax
    ).T


def wanda_score(w, act_norm):
    """Wanda importance  Psi(W) = |W| * ||X||_2  (Sun et al. 2023).

    w: (N, K), act_norm: (K,) = column-wise L2 norm of calibration inputs.
    """
    return jnp.abs(w) * act_norm[None, :]


def wanda_mask(w, act_norm, sparsity):
    """Per-output-row unstructured Wanda mask keeping the top (1-s) fraction."""
    n, k = w.shape
    scores = wanda_score(w, act_norm)
    keep = k - int(round(sparsity * k))
    order = jnp.argsort(scores, axis=1)[:, ::-1]
    ranks = jnp.argsort(order, axis=1)
    return (ranks < keep).astype(w.dtype)


def unpack_int4(packed):
    """(N, K//2) uint8 -> (N, K) int32 in [0, 15]; low nibble first."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def int4_dequant(packed, scales, zeros):
    """Dequantize packed INT4 weights to f32.  packed: (N, K//2) uint8."""
    q = unpack_int4(packed).astype(jnp.float32)
    n, k = q.shape
    g = scales.shape[1]
    gs = k // g
    qg = q.reshape(n, g, gs)
    return ((qg - zeros[:, :, None]) * scales[:, :, None]).reshape(n, k)


def int4_matmul(x, packed, scales, zeros):
    """y = x @ dequant(packed).T — the serving-path projection for merged
    QA-SparsePEFT models."""
    return x @ int4_dequant(packed, scales, zeros).T
