"""INT4 dequantize-matmul kernel — the serving path of a merged
QA-SparsePEFT model.

Weights live packed two-nibbles-per-byte in HBM (the whole point of the
paper's INT4 "Final Precision" column); each grid step unpacks one (bn, K/2)
tile to (bn, K) codes in VMEM, dequantizes group-wise on the VPU and feeds
the MXU.  HBM traffic is ~4x lower than the FP16 path, which is where the
Table 7 inference-memory ordering (4 < 2 < 3 < 1) comes from.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocks import pick_block


def _int4_kernel(x_ref, p_ref, s_ref, z_ref, o_ref):
    packed = p_ref[...].astype(jnp.int32)             # (bn, K//2)
    lo = (packed & 0xF).astype(jnp.float32)
    hi = ((packed >> 4) & 0xF).astype(jnp.float32)
    bn = packed.shape[0]
    q = jnp.stack([lo, hi], axis=-1).reshape(bn, -1)  # (bn, K) codes
    k = q.shape[1]
    g = s_ref[...].shape[1]
    qg = q.reshape(bn, g, k // g)
    w = ((qg - z_ref[...][:, :, None]) * s_ref[...][:, :, None]).reshape(bn, k)
    o_ref[...] = jnp.dot(x_ref[...], w.T)             # (bm, bn)


def int4_matmul(x, packed, scales, zeros):
    """y = x @ dequant(packed).T.

    x: (M, K) f32, packed: (N, K//2) uint8, scales/zeros: (N, G) f32.
    """
    m, k = x.shape
    n = packed.shape[0]
    g = scales.shape[1]
    bm = pick_block(m)
    bn = pick_block(n)
    return pl.pallas_call(
        _int4_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k // 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, scales, zeros)
