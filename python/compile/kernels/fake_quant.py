"""Standalone group-wise fake-quantization kernel (paper Eq. 3-4).

Used by the merge path (``fakequant_{m}x{n}`` artifacts): the coordinator
calls it once at merge time to realize Eq. 3 on (W^p + L^p), and the result is
bit-identical to what the QA-SparsePEFT train step computed on-the-fly — the
property the paper's "mergeable without accuracy loss" claim rests on.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocks import pick_block


def _fq_kernel(w_ref, s_ref, z_ref, qmax_ref, o_ref):
    qmax = qmax_ref[0]
    w = w_ref[...]
    bn, k = w.shape
    g = s_ref[...].shape[1]
    wg = w.reshape(bn, g, k // g)
    q = jnp.clip(
        jnp.round(wg / s_ref[...][:, :, None]) + z_ref[...][:, :, None],
        0.0, qmax,
    )
    o_ref[...] = ((q - z_ref[...][:, :, None]) * s_ref[...][:, :, None]).reshape(bn, k)


def _quant_kernel(w_ref, s_ref, z_ref, qmax_ref, o_ref):
    """Integer codes (as f32 for PJRT-friendliness): clamp(round(w/s)+z)."""
    qmax = qmax_ref[0]
    w = w_ref[...]
    bn, k = w.shape
    g = s_ref[...].shape[1]
    wg = w.reshape(bn, g, k // g)
    q = jnp.clip(
        jnp.round(wg / s_ref[...][:, :, None]) + z_ref[...][:, :, None],
        0.0, qmax,
    )
    o_ref[...] = q.reshape(bn, k)


def _call(kernel, w, scales, zeros, qmax):
    n, k = w.shape
    g = scales.shape[1]
    bn = pick_block(n)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, g), lambda i: (i, 0)),
            pl.BlockSpec((bn, g), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), w.dtype),
        interpret=True,
    )(w, scales, zeros, qmax)


def fake_quant(w, scales, zeros, qmax):
    """Dequantized fake-quant value s*(clamp(round(w/s)+z,0,qmax)-z)."""
    return _call(_fq_kernel, w, scales, zeros, qmax)


def quantize_codes(w, scales, zeros, qmax):
    """Integer quantization codes of Eq. 3, returned as f32."""
    return _call(_quant_kernel, w, scales, zeros, qmax)
