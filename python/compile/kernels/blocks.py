"""Block-size selection shared by the SQFT Pallas kernels.

TPU mapping rationale (DESIGN.md §Hardware-Adaptation): the MXU systolic array
is 128x128 and VMEM is ~16 MiB/core, so we prefer 128-aligned tiles and shrink
toward the actual dimension when the problem is smaller.  On CPU the kernels
run under interpret=True, where block shape only affects the lowered HLO
structure, not machine tiling — we still pick MXU-friendly shapes so the same
BlockSpecs are valid for a real Mosaic lowering.
"""


PREFERRED = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def pick_block(dim: int, cap: int = 128) -> int:
    """Largest preferred block <= cap that divides ``dim``."""
    for b in PREFERRED:
        if b <= cap and dim % b == 0:
            return b
    return 1


def vmem_bytes_f32(*shapes) -> int:
    """Static VMEM footprint estimate for a set of f32 blocks (for §Perf)."""
    total = 0
    for s in shapes:
        n = 4
        for d in s:
            n *= d
        total += n
    return total
