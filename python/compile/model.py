"""Layer-2: the adapted transformer (JAX), lowered AOT for the rust runtime.

A GPT-style decoder-only model (RMSNorm, RoPE causal attention, SwiGLU MLP)
whose Q, K, V, Up and Down projections — the paper's adapter target modules
(Table 8) — run through the Layer-1 fused SparsePEFT / QA-SparsePEFT Pallas
kernels.  Everything here executes exactly once, at `make artifacts` time;
the rust coordinator then drives the lowered HLO through PJRT.

Artifact functions (see DESIGN.md §5 for the full contract):
  - train_step      SparsePEFT/LoRA/Shears fine-tune step, Adam inside graph
  - train_qa_step   QA-SparsePEFT fine-tune step (shared-scale fake quant, STE)
  - eval_step       batched forward -> logits (mask/rank-mask parameterized)
  - eval_qa_step    forward through the fake-quantized merged weights
  - calib_step      forward that also captures per-site activations for
                    Wanda column norms and GPTQ Hessians

All layer-indexed parameters are stacked on a leading L axis so the artifact
input list stays small and the rust side can hold one buffer per logical
tensor.  Input ordering is canonical: see ``train_input_specs`` etc.; aot.py
serializes it into artifacts/manifest.json, and rust/src/runtime/manifest.rs
checks it at load time.
"""

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels as K

# Adapter target modules, matching the paper's Q,K,V,Up,Down set (Table 8).
MODS = ("q", "k", "v", "up", "down")

# Every linear weight stack, in canonical (manifest) order — the set that is
# sparsified/quantized and, for the packed-INT4 serving path, stored as
# two-nibbles-per-byte codes (matching rust `model::linear_keys`).
LINEAR_KEYS = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static hyperparameters of one model variant (= one artifact set)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int
    r_max: int
    group_size: int = 32  # INT4 quantization group size along in-features
    # serve_only configs get just the serving artifacts (eval + the
    # prefill/decode pair) — used by the seq-length sweep variants so the
    # bench can scale context without paying for train/calib lowering.
    serve_only: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def mod_dims(self, mod: str) -> Tuple[int, int]:
        """(out_features, in_features) of an adapted module."""
        d, ff = self.d_model, self.d_ff
        return {"q": (d, d), "k": (d, d), "v": (d, d),
                "up": (ff, d), "down": (d, ff)}[mod]

    def mod_groups(self, mod: str) -> int:
        return self.mod_dims(mod)[1] // self.group_size

    def layer_shapes(self) -> List[Tuple[int, int]]:
        """Distinct (out, in) linear shapes — drives wanda/fakequant artifacts."""
        d, ff = self.d_model, self.d_ff
        return sorted({(d, d), (ff, d), (d, ff)})

    def linear_dims(self, wkey: str) -> Tuple[int, int]:
        """(out_features, in_features) of any linear weight stack."""
        d, ff = self.d_model, self.d_ff
        return {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
                "wgate": (ff, d), "wup": (ff, d), "wdown": (d, ff)}[wkey]

    def param_count(self) -> int:
        d, ff, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * ff * d + 2 * d
        return v * d + l * per_layer + d


CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        # ~0.9M params — unit/integration tests, fast CI
        ModelConfig("sqft-tiny", 64, 64, 2, 2, 128, 48, 8, 8),
        # ~4.2M params — table-reproduction workhorse
        ModelConfig("sqft-small", 64, 256, 4, 4, 1024, 64, 8, 16),
        # ~27M params — end-to-end example driver
        ModelConfig("sqft-base", 64, 512, 8, 8, 1536, 64, 8, 32),
        # ~100M params — scale reference config
        ModelConfig("sqft-large", 64, 768, 12, 12, 2560, 128, 8, 32),
        # seq-length sweep variants of sqft-tiny (serving artifacts only)
        # — same weights shapes, longer context, for BENCH_decode.json
        ModelConfig("sqft-tiny-s96", 64, 64, 2, 2, 128, 96, 8, 8,
                    serve_only=True),
        ModelConfig("sqft-tiny-s192", 64, 64, 2, 2, 128, 192, 8, 8,
                    serve_only=True),
    ]
}


# ---------------------------------------------------------------------------
# core model ops
# ---------------------------------------------------------------------------


def rms_norm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions):
    """Rotary position embedding over the last dim (rotate-half form).

    x: (B, S, H, Dh), positions: (S,)
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def adapted_proj(x2d, w, a, b, mask, rank_mask, scale, qparams=None):
    """Dispatch one adapted projection through the L1 kernel."""
    if qparams is None:
        return K.sparse_lora_matmul(x2d, w, a, b, mask, rank_mask, scale)
    qscales, qzeros, qmax = qparams
    return K.qa_sparse_lora_matmul(
        x2d, w, a, b, mask, rank_mask, scale, qscales, qzeros, qmax
    )


def forward(cfg: ModelConfig, base, adapters, tokens, qa=None, capture=False):
    """Adapted-transformer forward.

    base: dict of stacked frozen tensors (see ``base_param_specs``).
    adapters: dict with per-module stacks a_/b_/mask_/rankmask_/scale_.
    qa: None or dict with qscales_/qzeros_ stacks + qmax (1,).
    capture: also return per-site activations for calibration.
    Returns logits (B, S, V) [, captures].
    """
    bsz, seq = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = base["embed"][tokens]  # (B, S, d)
    positions = jnp.arange(seq)
    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    caps = {"xqkv": [], "xo": [], "xmlp": [], "xdown": []}

    def proj(mod, l, x2d):
        w = base["w" + mod][l] if mod in ("q", "k", "v") else base["w" + mod][l]
        qp = None
        if qa is not None:
            qp = (qa["qscales_" + mod][l], qa["qzeros_" + mod][l], qa["qmax"])
        return adapted_proj(
            x2d, w,
            adapters["a_" + mod][l], adapters["b_" + mod][l],
            adapters["mask_" + mod][l], adapters["rankmask_" + mod][l],
            adapters["scale_" + mod][l:l + 1], qp,
        )

    for l in range(cfg.n_layers):
        # --- attention block -------------------------------------------
        hln = rms_norm(x, base["ln1"][l])
        h2d = hln.reshape(bsz * seq, d)
        if capture:
            caps["xqkv"].append(h2d)
        q = proj("q", l, h2d).reshape(bsz, seq, h, dh)
        k = proj("k", l, h2d).reshape(bsz, seq, h, dh)
        v = proj("v", l, h2d).reshape(bsz, seq, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
        att = jnp.where(causal[None, None, :, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz * seq, d)
        if capture:
            caps["xo"].append(o)
        x = x + (o @ base["wo"][l].T).reshape(bsz, seq, d)

        # --- SwiGLU MLP block -------------------------------------------
        hln = rms_norm(x, base["ln2"][l])
        h2d = hln.reshape(bsz * seq, d)
        if capture:
            caps["xmlp"].append(h2d)
        gate = h2d @ base["wgate"][l].T
        up = proj("up", l, h2d)
        act = jax.nn.silu(gate) * up  # (B*S, ff)
        if capture:
            caps["xdown"].append(act)
        down = proj("down", l, act)
        x = x + down.reshape(bsz, seq, d)

    x = rms_norm(x, base["final_ln"])
    logits = x @ base["embed"].T
    if capture:
        stacks = {k2: jnp.stack(v2) for k2, v2 in caps.items()}
        return logits, stacks
    return logits


def loss_fn(cfg, base, adapters, tokens, targets, loss_mask, qa=None):
    """Masked next-token cross entropy (loss only on answer positions)."""
    logits = forward(cfg, base, adapters, tokens, qa=qa)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.sum(nll * loss_mask) / denom


# ---------------------------------------------------------------------------
# train / eval step builders
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def _adam_update(p, g, m, v, step, lr):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1 ** step)
    vhat = v / (1 - ADAM_B2 ** step)
    return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def base_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    d, ff, v, l = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    return [
        ("embed", (v, d)),
        ("final_ln", (d,)),
        ("ln1", (l, d)),
        ("ln2", (l, d)),
        ("wq", (l, d, d)),
        ("wk", (l, d, d)),
        ("wv", (l, d, d)),
        ("wo", (l, d, d)),
        ("wgate", (l, ff, d)),
        ("wup", (l, ff, d)),
        ("wdown", (l, d, ff)),
    ]


def adapter_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    l, r = cfg.n_layers, cfg.r_max
    specs = []
    for m in MODS:
        out, inp = cfg.mod_dims(m)
        specs.append((f"a_{m}", (l, r, inp)))
    for m in MODS:
        out, inp = cfg.mod_dims(m)
        specs.append((f"b_{m}", (l, out, r)))
    for m in MODS:
        out, inp = cfg.mod_dims(m)
        specs.append((f"mask_{m}", (l, out, inp)))
    for m in MODS:
        specs.append((f"rankmask_{m}", (l, r)))
    for m in MODS:
        specs.append((f"scale_{m}", (l,)))
    return specs


def qa_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    l = cfg.n_layers
    specs = []
    for m in MODS:
        out, _ = cfg.mod_dims(m)
        specs.append((f"qscales_{m}", (l, out, cfg.mod_groups(m))))
    for m in MODS:
        out, _ = cfg.mod_dims(m)
        specs.append((f"qzeros_{m}", (l, out, cfg.mod_groups(m))))
    specs.append(("qmax", (1,)))
    return specs


def opt_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    l, r = cfg.n_layers, cfg.r_max
    specs = []
    for kind in ("m", "v"):
        for m in MODS:
            out, inp = cfg.mod_dims(m)
            specs.append((f"{kind}_a_{m}", (l, r, inp)))
        for m in MODS:
            out, inp = cfg.mod_dims(m)
            specs.append((f"{kind}_b_{m}", (l, out, r)))
    return specs


def batch_specs(cfg: ModelConfig, with_targets=True):
    b, s = cfg.batch, cfg.seq_len
    specs = [("tokens", (b, s), jnp.int32)]
    if with_targets:
        specs += [
            ("targets", (b, s), jnp.int32),
            ("loss_mask", (b, s), jnp.float32),
            ("step", (1,), jnp.float32),
            ("lr", (1,), jnp.float32),
        ]
    return specs


def train_input_specs(cfg: ModelConfig, qa: bool):
    """Canonical input ordering for the train artifacts."""
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    specs += [(n, s, jnp.float32) for n, s in adapter_param_specs(cfg)]
    if qa:
        specs += [(n, s, jnp.float32) for n, s in qa_param_specs(cfg)]
    specs += [(n, s, jnp.float32) for n, s in opt_param_specs(cfg)]
    specs += batch_specs(cfg, with_targets=True)
    return specs


def eval_input_specs(cfg: ModelConfig, qa: bool):
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    specs += [(n, s, jnp.float32) for n, s in adapter_param_specs(cfg)]
    if qa:
        specs += [(n, s, jnp.float32) for n, s in qa_param_specs(cfg)]
    specs += batch_specs(cfg, with_targets=False)
    return specs


def calib_input_specs(cfg: ModelConfig):
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    specs += [(n, s, jnp.float32) for n, s in adapter_param_specs(cfg)]
    specs += batch_specs(cfg, with_targets=False)
    return specs


def _unflatten(cfg, args, qa):
    """Rebuild the base/adapters/qa dicts from positional args."""
    names_base = [n for n, _ in base_param_specs(cfg)]
    names_ad = [n for n, _ in adapter_param_specs(cfg)]
    i = 0
    base = {}
    for n in names_base:
        base[n] = args[i]
        i += 1
    adapters = {}
    for n in names_ad:
        adapters[n] = args[i]
        i += 1
    qad = None
    if qa:
        qad = {}
        for n, _ in qa_param_specs(cfg):
            qad[n] = args[i]
            i += 1
    return base, adapters, qad, i


def make_train_step(cfg: ModelConfig, qa: bool):
    """Build the positional train-step function for AOT lowering.

    Returns (new adapter a/b stacks in MODS order, new m/v stacks, loss).
    """
    trainable = [f"a_{m}" for m in MODS] + [f"b_{m}" for m in MODS]

    def step_fn(*args):
        base, adapters, qad, i = _unflatten(cfg, args, qa)
        opt = {}
        for n, _ in opt_param_specs(cfg):
            opt[n] = args[i]
            i += 1
        tokens, targets, loss_mask, step, lr = args[i:i + 5]

        def closure(train_params):
            ad = dict(adapters)
            ad.update(train_params)
            return loss_fn(cfg, base, ad, tokens, targets, loss_mask, qa=qad)

        tp = {n: adapters[n] for n in trainable}
        loss, grads = jax.value_and_grad(closure)(tp)
        outs = []
        new_m, new_v = [], []
        st = step[0]
        lrv = lr[0]
        for n in trainable:
            p, m_, v_ = _adam_update(
                tp[n], grads[n], opt["m_" + n], opt["v_" + n], st, lrv
            )
            outs.append(p)
            new_m.append(m_)
            new_v.append(v_)
        return tuple(outs + new_m + new_v + [jnp.reshape(loss, (1,))])

    return step_fn


def train_output_names(cfg: ModelConfig) -> List[str]:
    trainable = [f"a_{m}" for m in MODS] + [f"b_{m}" for m in MODS]
    return (
        trainable
        + ["m_" + n for n in trainable]
        + ["v_" + n for n in trainable]
        + ["loss"]
    )


def make_eval_step(cfg: ModelConfig, qa: bool):
    def eval_fn(*args):
        base, adapters, qad, i = _unflatten(cfg, args, qa)
        tokens = args[i]
        logits = forward(cfg, base, adapters, tokens, qa=qad)
        return (logits,)

    return eval_fn


def make_calib_step(cfg: ModelConfig):
    """Forward capturing the four linear-input activation sites.

    Outputs: logits, xqkv (L,T,d), xo (L,T,d), xmlp (L,T,d), xdown (L,T,ff)
    with T = batch*seq — consumed by the rust Wanda/GPTQ drivers.
    """

    def calib_fn(*args):
        base, adapters, _, i = _unflatten(cfg, args, qa=False)
        tokens = args[i]
        logits, caps = forward(cfg, base, adapters, tokens, capture=True)
        return (logits, caps["xqkv"], caps["xo"], caps["xmlp"], caps["xdown"])

    return calib_fn


def calib_output_names() -> List[str]:
    return ["logits", "xqkv", "xo", "xmlp", "xdown"]


# --- pretraining (full-weight) path ----------------------------------------
#
# The SQFT pipeline starts from a *pretrained* base model.  The paper uses
# HF checkpoints; here (DESIGN.md §1) we pretrain the synthetic-task base
# ourselves, which needs gradients w.r.t. every base weight.  The adapted
# forward cannot be reused for this: the L1 kernels' custom_vjp freezes W
# (PEFT semantics), so pretraining uses a plain-jnp forward.


def forward_plain(cfg: ModelConfig, base, tokens):
    """Unadapted forward (no adapters, no masks) for pretraining."""
    bsz, seq = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = base["embed"][tokens]
    positions = jnp.arange(seq)
    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    for l in range(cfg.n_layers):
        hln = rms_norm(x, base["ln1"][l])
        h2d = hln.reshape(bsz * seq, d)
        q = (h2d @ base["wq"][l].T).reshape(bsz, seq, h, dh)
        k = (h2d @ base["wk"][l].T).reshape(bsz, seq, h, dh)
        v = (h2d @ base["wv"][l].T).reshape(bsz, seq, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
        att = jnp.where(causal[None, None, :, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz * seq, d)
        x = x + (o @ base["wo"][l].T).reshape(bsz, seq, d)
        hln = rms_norm(x, base["ln2"][l])
        h2d = hln.reshape(bsz * seq, d)
        act = jax.nn.silu(h2d @ base["wgate"][l].T) * (h2d @ base["wup"][l].T)
        x = x + (act @ base["wdown"][l].T).reshape(bsz, seq, d)
    x = rms_norm(x, base["final_ln"])
    return x @ base["embed"].T


def pretrain_input_specs(cfg: ModelConfig):
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    for kind in ("m", "v"):
        specs += [(f"{kind}_{n}", s, jnp.float32) for n, s in base_param_specs(cfg)]
    specs += batch_specs(cfg, with_targets=True)
    return specs


def pretrain_output_names(cfg: ModelConfig) -> List[str]:
    names = [n for n, _ in base_param_specs(cfg)]
    return names + ["m_" + n for n in names] + ["v_" + n for n in names] + ["loss"]


def make_pretrain_step(cfg: ModelConfig):
    names = [n for n, _ in base_param_specs(cfg)]

    def step_fn(*args):
        base = {n: a for (n, _), a in zip(base_param_specs(cfg), args)}
        i = len(names)
        opt = {}
        for kind in ("m", "v"):
            for n in names:
                opt[f"{kind}_{n}"] = args[i]
                i += 1
        tokens, targets, loss_mask, step, lr = args[i:i + 5]

        def closure(params):
            logits = forward_plain(cfg, params, tokens)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
            denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
            return jnp.sum(nll * loss_mask) / denom

        loss, grads = jax.value_and_grad(closure)(base)
        outs, ms, vs = [], [], []
        for n in names:
            p, m_, v_ = _adam_update(
                base[n], grads[n], opt["m_" + n], opt["v_" + n], step[0], lr[0])
            outs.append(p)
            ms.append(m_)
            vs.append(v_)
        return tuple(outs + ms + vs + [jnp.reshape(loss, (1,))])

    return step_fn


# --- packed-INT4 serving path (merged QA-SparsePEFT models) ----------------
#
# A merged quantized-base model is fully INT4-representable: every linear
# weight stack exists as integer codes + shared group params (paper Eq. 3).
# The serving artifact keeps the codes packed two-nibbles-per-byte in HBM and
# dequantizes tile-wise inside the L1 int4 kernel, so resident weight memory
# is the Table 7 INT4 figure rather than a dense f32 copy.  No adapter
# inputs: the model is merged, adapters are gone by construction.


def forward_int4(cfg: ModelConfig, params, tokens):
    """Forward through packed-INT4 linear weights.

    params: dict with embed/final_ln/ln1/ln2 (f32), packed_<wkey> uint8
    stacks (L, out, in//2), and qscales_<wkey>/qzeros_<wkey> (L, out, G).
    """
    bsz, seq = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]
    positions = jnp.arange(seq)
    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))

    def lin(wkey, l, x2d):
        return K.int4_matmul(
            x2d,
            params[f"packed_{wkey}"][l],
            params[f"qscales_{wkey}"][l],
            params[f"qzeros_{wkey}"][l],
        )

    for l in range(cfg.n_layers):
        hln = rms_norm(x, params["ln1"][l])
        h2d = hln.reshape(bsz * seq, d)
        q = lin("wq", l, h2d).reshape(bsz, seq, h, dh)
        k = lin("wk", l, h2d).reshape(bsz, seq, h, dh)
        v = lin("wv", l, h2d).reshape(bsz, seq, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
        att = jnp.where(causal[None, None, :, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz * seq, d)
        x = x + lin("wo", l, o).reshape(bsz, seq, d)
        hln = rms_norm(x, params["ln2"][l])
        h2d = hln.reshape(bsz * seq, d)
        act = jax.nn.silu(lin("wgate", l, h2d)) * lin("wup", l, h2d)
        x = x + lin("wdown", l, act).reshape(bsz, seq, d)
    x = rms_norm(x, params["final_ln"])
    return x @ params["embed"].T


def int4_param_specs(cfg: ModelConfig):
    """Canonical inputs of the eval_int4 artifact (without the batch)."""
    d, v, l = cfg.d_model, cfg.vocab, cfg.n_layers
    specs = [
        ("embed", (v, d), jnp.float32),
        ("final_ln", (d,), jnp.float32),
        ("ln1", (l, d), jnp.float32),
        ("ln2", (l, d), jnp.float32),
    ]
    for wkey in LINEAR_KEYS:
        out, inp = cfg.linear_dims(wkey)
        specs.append((f"packed_{wkey}", (l, out, inp // 2), jnp.uint8))
    for wkey in LINEAR_KEYS:
        out, inp = cfg.linear_dims(wkey)
        g = inp // cfg.group_size
        specs.append((f"qscales_{wkey}", (l, out, g), jnp.float32))
    for wkey in LINEAR_KEYS:
        out, inp = cfg.linear_dims(wkey)
        g = inp // cfg.group_size
        specs.append((f"qzeros_{wkey}", (l, out, g), jnp.float32))
    return specs


def eval_int4_input_specs(cfg: ModelConfig):
    return int4_param_specs(cfg) + batch_specs(cfg, with_targets=False)


def make_eval_int4_step(cfg: ModelConfig):
    names = [n for n, _, _ in int4_param_specs(cfg)]

    def eval_fn(*args):
        params = dict(zip(names, args))
        tokens = args[len(names)]
        logits = forward_int4(cfg, params, tokens)
        return (logits,)

    return eval_fn


# --- gathered multi-tenant serving path ------------------------------------
#
# One forward serves a *mixed* batch of tenants: per-tenant adapters are
# stacked into device-resident banks with a leading slot axis T, and a
# per-row i32 ``adapter_idx`` picks each row's slice inside the L1
# gathered kernel (S-LoRA/punica style).  Bank slot 0 is reserved for
# the identity adapter (B = 0), so rows with no tenant — the merged /
# ``adapter_id: None`` path — batch together with adapted rows and still
# compute the plain base projection.  The Wanda mask belongs to the
# shared sparsified base (same for every tenant) and stays un-banked.

# Adapter-bank slots per artifact (slot 0 = identity).  Static so the
# lowered HLO has fixed shapes; the rust registry reads the slot count
# back from the manifest input specs, never from this constant.
GATHER_SLOTS = 9


def gathered_bank_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Stacked adapter banks, slot-major so one tenant's slice is one
    contiguous block the registry can overwrite on (re-)registration."""
    l, r, t = cfg.n_layers, cfg.r_max, GATHER_SLOTS
    specs = []
    for m in MODS:
        out, inp = cfg.mod_dims(m)
        specs.append((f"a_bank_{m}", (t, l, r, inp)))
    for m in MODS:
        out, inp = cfg.mod_dims(m)
        specs.append((f"b_bank_{m}", (t, l, out, r)))
    for m in MODS:
        specs.append((f"rankmask_bank_{m}", (t, l, r)))
    for m in MODS:
        specs.append((f"scale_bank_{m}", (t, l)))
    return specs


def forward_gathered(cfg: ModelConfig, params, tokens, adapter_idx):
    """Mixed-tenant forward: row b of the batch uses bank slot
    ``adapter_idx[b]`` in every adapted projection.

    params: base stacks + shared ``mask_<mod>`` + the gathered banks
    (see ``eval_gathered_input_specs``).  adapter_idx: (B,) int32.
    """
    bsz, seq = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]
    positions = jnp.arange(seq)
    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    # every activation row of a request carries that request's adapter
    row_idx = jnp.repeat(adapter_idx, seq)  # (B*S,)

    def proj(mod, l, x2d):
        return K.gathered_sparse_lora_matmul(
            x2d, params["w" + mod][l],
            params[f"a_bank_{mod}"][:, l], params[f"b_bank_{mod}"][:, l],
            params[f"mask_{mod}"][l], params[f"rankmask_bank_{mod}"][:, l],
            params[f"scale_bank_{mod}"][:, l], row_idx,
        )

    for l in range(cfg.n_layers):
        hln = rms_norm(x, params["ln1"][l])
        h2d = hln.reshape(bsz * seq, d)
        q = proj("q", l, h2d).reshape(bsz, seq, h, dh)
        k = proj("k", l, h2d).reshape(bsz, seq, h, dh)
        v = proj("v", l, h2d).reshape(bsz, seq, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
        att = jnp.where(causal[None, None, :, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz * seq, d)
        x = x + (o @ params["wo"][l].T).reshape(bsz, seq, d)
        hln = rms_norm(x, params["ln2"][l])
        h2d = hln.reshape(bsz * seq, d)
        gate = h2d @ params["wgate"][l].T
        up = proj("up", l, h2d)
        act = jax.nn.silu(gate) * up
        down = proj("down", l, act)
        x = x + down.reshape(bsz, seq, d)
    x = rms_norm(x, params["final_ln"])
    return x @ params["embed"].T


def eval_gathered_input_specs(cfg: ModelConfig):
    """Canonical eval_gathered inputs: base, shared masks, banks, batch.

    The batch is tokens plus the per-row ``adapter_idx`` vector — the
    only two inputs the steady-state decode loop uploads per step.
    """
    specs = gathered_param_specs(cfg)
    specs += batch_specs(cfg, with_targets=False)
    specs.append(("adapter_idx", (cfg.batch,), jnp.int32))
    return specs


def make_eval_gathered_step(cfg: ModelConfig):
    names = [n for n, _, _ in eval_gathered_input_specs(cfg)[:-2]]

    def eval_fn(*args):
        params = dict(zip(names, args))
        tokens = args[len(names)]
        adapter_idx = args[len(names) + 1]
        logits = forward_gathered(cfg, params, tokens, adapter_idx)
        return (logits,)

    return eval_fn


# --- KV-cached serving path: prefill / decode_step split --------------------
#
# The serving hot loop used to re-run the full causal forward over the whole
# flattened (slots, seq) token buffer on every step — O(seq) per token.  The
# cached split lowers two artifacts per eval kind instead:
#
#   prefill      full causal forward over the token buffer that *also* emits
#                every layer's post-RoPE K and raw V, packed per slot into a
#                single device-resident state tensor (slots, kv_state_elems);
#                ``seq_lens`` picks each row's frontier logits (len-1).
#   decode_step  one token per row + the resident state: single-position
#                RoPE/attention against the cached K/V, writing the new K/V
#                at the row's current length — O(1) in sequence length.
#   decode_out   cheap readout slicing the frontier logits (slots, V) off the
#                state tail, so the per-step host download stays tiny.
#
# The state is ONE tensor (not per-layer outputs) so the artifact has a
# single array result that the rust runtime can keep on device between calls
# and feed back as the next step's input without a host round-trip; packed
# layout per slot: [K (L,S,H,Dh) | V (L,S,H,Dh) | frontier logits (V,)].
# Positions >= the row's length hold garbage (padding-token K/V) — decode
# masks attention to 0..pos and overwrites position pos, so they are never
# read, which is also what makes slot refill a pure prefill with no explicit
# page-clearing step.  The QA path keeps the legacy full-forward loop.


def kv_state_elems(cfg: ModelConfig) -> int:
    """Per-slot packed-state width: K + V caches + frontier logits."""
    return 2 * cfg.n_layers * cfg.seq_len * cfg.d_model + cfg.vocab


def rope_rows(x, positions):
    """Rotary embedding at one per-row position (decode-step form).

    x: (B, H, Dh), positions: (B,) int32 — same rotate-half math as
    ``rope`` so cached K entries are bitwise those of the full forward.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (B, half)
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _transformer_prefill(cfg: ModelConfig, params, lin, tokens, lens):
    """Full causal forward emitting the packed KV state.

    ``lin(kind, l, x2d)`` dispatches one linear site — kind is one of
    q/k/v/o/gate/up/down — so each eval path (adapter, gathered, INT4)
    plugs in its own projection while the attention math stays identical
    to that path's full forward.  Returns the packed state (B, P).
    """
    bsz, seq = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]
    positions = jnp.arange(seq)
    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    ks, vs = [], []
    for l in range(cfg.n_layers):
        hln = rms_norm(x, params["ln1"][l])
        h2d = hln.reshape(bsz * seq, d)
        q = lin("q", l, h2d).reshape(bsz, seq, h, dh)
        k = lin("k", l, h2d).reshape(bsz, seq, h, dh)
        v = lin("v", l, h2d).reshape(bsz, seq, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
        att = jnp.where(causal[None, None, :, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz * seq, d)
        x = x + lin("o", l, o).reshape(bsz, seq, d)
        hln = rms_norm(x, params["ln2"][l])
        h2d = hln.reshape(bsz * seq, d)
        act = jax.nn.silu(lin("gate", l, h2d)) * lin("up", l, h2d)
        x = x + lin("down", l, act).reshape(bsz, seq, d)
        ks.append(k)
        vs.append(v)
    x = rms_norm(x, params["final_ln"])
    logits = x @ params["embed"].T  # (B, S, V)
    sel = jnp.clip(lens - 1, 0, seq - 1)
    frontier_logits = jnp.take_along_axis(
        logits, sel[:, None, None], axis=1)[:, 0, :]
    kc = jnp.stack(ks, axis=1)  # (B, L, S, H, Dh)
    vc = jnp.stack(vs, axis=1)
    return jnp.concatenate(
        [kc.reshape(bsz, -1), vc.reshape(bsz, -1), frontier_logits], axis=1)


def _transformer_decode(cfg: ModelConfig, params, lin, state, frontier, pos):
    """Single-position cached forward over the resident KV state.

    Consumes one frontier token per row at absolute position ``pos``,
    writes its post-RoPE K / raw V into the cache at that position, and
    attends over 0..pos with the same -1e30 masking as the full forward
    (masked exponentials underflow to exactly 0.0, so the softmax
    denominator matches the causal reference).  Returns the updated
    packed state with the new frontier logits in the tail.
    """
    bsz = frontier.shape[0]
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    seq = cfg.seq_len
    n = cfg.n_layers * seq * d
    kc = state[:, :n].reshape(bsz, cfg.n_layers, seq, h, dh)
    vc = state[:, n:2 * n].reshape(bsz, cfg.n_layers, seq, h, dh)
    x = params["embed"][frontier]  # (B, d)
    write = jnp.arange(seq)[None, :] == pos[:, None]   # (B, S)
    attend = jnp.arange(seq)[None, :] <= pos[:, None]  # (B, S)
    ks, vs = [], []
    for l in range(cfg.n_layers):
        hln = rms_norm(x, params["ln1"][l])
        q = lin("q", l, hln).reshape(bsz, h, dh)
        k = lin("k", l, hln).reshape(bsz, h, dh)
        v = lin("v", l, hln).reshape(bsz, h, dh)
        q = rope_rows(q, pos)
        k = rope_rows(k, pos)
        kl = jnp.where(write[:, :, None, None], k[:, None, :, :], kc[:, l])
        vl = jnp.where(write[:, :, None, None], v[:, None, :, :], vc[:, l])
        att = jnp.einsum("bhd,bshd->bhs", q, kl) / math.sqrt(dh)
        att = jnp.where(attend[:, None, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhs,bshd->bhd", att, vl).reshape(bsz, d)
        x = x + lin("o", l, o)
        hln = rms_norm(x, params["ln2"][l])
        act = jax.nn.silu(lin("gate", l, hln)) * lin("up", l, hln)
        x = x + lin("down", l, act)
        ks.append(kl)
        vs.append(vl)
    x = rms_norm(x, params["final_ln"])
    logits = x @ params["embed"].T  # (B, V)
    kc2 = jnp.stack(ks, axis=1)
    vc2 = jnp.stack(vs, axis=1)
    return jnp.concatenate(
        [kc2.reshape(bsz, -1), vc2.reshape(bsz, -1), logits], axis=1)


def _adapted_lin(cfg: ModelConfig, base, adapters):
    """Linear-site dispatch for the plain/adapter serving path."""

    def lin(kind, l, x2d):
        if kind in ("o", "gate"):
            return x2d @ base["w" + kind][l].T
        return adapted_proj(
            x2d, base["w" + kind][l],
            adapters["a_" + kind][l], adapters["b_" + kind][l],
            adapters["mask_" + kind][l], adapters["rankmask_" + kind][l],
            adapters["scale_" + kind][l:l + 1], None,
        )

    return lin


def _gathered_lin(cfg: ModelConfig, params, row_idx):
    """Linear-site dispatch for the mixed-tenant gathered path."""

    def lin(kind, l, x2d):
        if kind in ("o", "gate"):
            return x2d @ params["w" + kind][l].T
        return K.gathered_sparse_lora_matmul(
            x2d, params["w" + kind][l],
            params[f"a_bank_{kind}"][:, l], params[f"b_bank_{kind}"][:, l],
            params[f"mask_{kind}"][l], params[f"rankmask_bank_{kind}"][:, l],
            params[f"scale_bank_{kind}"][:, l], row_idx,
        )

    return lin


def _int4_lin(params):
    """Linear-site dispatch for the packed-INT4 merged path."""

    def lin(kind, l, x2d):
        wkey = "w" + kind
        return K.int4_matmul(
            x2d, params[f"packed_{wkey}"][l],
            params[f"qscales_{wkey}"][l], params[f"qzeros_{wkey}"][l],
        )

    return lin


def kv_batch_specs(cfg: ModelConfig, prefill: bool):
    """Hot-loop inputs of the cached pair.

    prefill re-ships the whole token buffer (it reruns every slot, so
    admission cost equals one legacy decode step); decode_step ships only
    the per-row frontier token and absolute position — O(1) in seq_len.
    """
    b, s = cfg.batch, cfg.seq_len
    if prefill:
        return [("tokens", (b, s), jnp.int32), ("seq_lens", (b,), jnp.int32)]
    return [
        ("kv_state", (b, kv_state_elems(cfg)), jnp.float32),
        ("frontier", (b,), jnp.int32),
        ("positions", (b,), jnp.int32),
    ]


def prefill_input_specs(cfg: ModelConfig):
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    specs += [(n, s, jnp.float32) for n, s in adapter_param_specs(cfg)]
    specs += kv_batch_specs(cfg, prefill=True)
    return specs


def decode_input_specs(cfg: ModelConfig):
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    specs += [(n, s, jnp.float32) for n, s in adapter_param_specs(cfg)]
    specs += kv_batch_specs(cfg, prefill=False)
    return specs


def make_prefill_step(cfg: ModelConfig):
    def fn(*args):
        base, adapters, _, i = _unflatten(cfg, args, qa=False)
        tokens, lens = args[i], args[i + 1]
        lin = _adapted_lin(cfg, base, adapters)
        return (_transformer_prefill(cfg, base, lin, tokens, lens),)

    return fn


def make_decode_step(cfg: ModelConfig):
    def fn(*args):
        base, adapters, _, i = _unflatten(cfg, args, qa=False)
        state, frontier, pos = args[i], args[i + 1], args[i + 2]
        lin = _adapted_lin(cfg, base, adapters)
        return (_transformer_decode(cfg, base, lin, state, frontier, pos),)

    return fn


def gathered_param_specs(cfg: ModelConfig):
    """Base + shared masks + banks — everything but the batch inputs."""
    l = cfg.n_layers
    specs = [(n, s, jnp.float32) for n, s in base_param_specs(cfg)]
    for m in MODS:
        out, inp = cfg.mod_dims(m)
        specs.append((f"mask_{m}", (l, out, inp), jnp.float32))
    specs += [(n, s, jnp.float32) for n, s in gathered_bank_specs(cfg)]
    return specs


def prefill_gathered_input_specs(cfg: ModelConfig):
    specs = gathered_param_specs(cfg)
    specs += kv_batch_specs(cfg, prefill=True)
    specs.append(("adapter_idx", (cfg.batch,), jnp.int32))
    return specs


def decode_gathered_input_specs(cfg: ModelConfig):
    specs = gathered_param_specs(cfg)
    specs += kv_batch_specs(cfg, prefill=False)
    specs.append(("adapter_idx", (cfg.batch,), jnp.int32))
    return specs


def make_prefill_gathered_step(cfg: ModelConfig):
    names = [n for n, _, _ in gathered_param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args))
        tokens, lens, adapter_idx = args[len(names):len(names) + 3]
        row_idx = jnp.repeat(adapter_idx, cfg.seq_len)
        lin = _gathered_lin(cfg, params, row_idx)
        return (_transformer_prefill(cfg, params, lin, tokens, lens),)

    return fn


def make_decode_gathered_step(cfg: ModelConfig):
    names = [n for n, _, _ in gathered_param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args))
        state, frontier, pos, adapter_idx = args[len(names):len(names) + 4]
        lin = _gathered_lin(cfg, params, adapter_idx)
        return (_transformer_decode(cfg, params, lin, state, frontier, pos),)

    return fn


def prefill_int4_input_specs(cfg: ModelConfig):
    return int4_param_specs(cfg) + kv_batch_specs(cfg, prefill=True)


def decode_int4_input_specs(cfg: ModelConfig):
    return int4_param_specs(cfg) + kv_batch_specs(cfg, prefill=False)


def make_prefill_int4_step(cfg: ModelConfig):
    names = [n for n, _, _ in int4_param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args))
        tokens, lens = args[len(names):len(names) + 2]
        return (_transformer_prefill(cfg, params, _int4_lin(params),
                                     tokens, lens),)

    return fn


def make_decode_int4_step(cfg: ModelConfig):
    names = [n for n, _, _ in int4_param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args))
        state, frontier, pos = args[len(names):len(names) + 3]
        return (_transformer_decode(cfg, params, _int4_lin(params),
                                    state, frontier, pos),)

    return fn


def decode_out_input_specs(cfg: ModelConfig):
    return [("kv_state", (cfg.batch, kv_state_elems(cfg)), jnp.float32)]


def make_decode_out_step(cfg: ModelConfig):
    """Frontier-logits readout: the only per-step device->host transfer."""
    off = 2 * cfg.n_layers * cfg.seq_len * cfg.d_model

    def fn(state):
        return (state[:, off:],)

    return fn


# --- per-shape utility artifacts -------------------------------------------


def make_wanda(m: int, n: int):
    """Wanda scores for one (m, n) weight shape via the L1 kernel."""

    def fn(w, act_norm):
        return (K.wanda_score(w, act_norm),)

    return fn


def make_fakequant(m: int, n: int, group_size: int):
    """Eq. 3-4 for one (m, n) weight shape: (dequantized, integer codes)."""

    def fn(w, scales, zeros, qmax):
        return (
            K.fake_quant(w, scales, zeros, qmax),
            K.quantize_codes(w, scales, zeros, qmax),
        )

    return fn
